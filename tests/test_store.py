"""Store substrate tests: recordio, B+-tree, LSM vs dict oracles."""

import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core import Foreactor, MemDevice, io
from repro.store import plugins
from repro.store.bptree import BPTree
from repro.store.fileutils import cp_file, du_dir
from repro.store.lsm import LSMTree
from repro.store.recordio import RecordShardReader, RecordShardWriter


# -- recordio ----------------------------------------------------------------
def test_recordio_roundtrip():
    dev = MemDevice()
    w = RecordShardWriter(dev, "/s.rio", 16)
    recs = [bytes([i]) * 16 for i in range(10)]
    for r in recs:
        w.append(r)
    w.close()
    rd = RecordShardReader(dev, "/s.rio")
    assert len(rd) == 10
    assert [rd.read_record(i) for i in range(10)] == recs
    with pytest.raises(IndexError):
        rd.read_record(10)


# -- B+-tree -------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 400),
    degree=st.integers(4, 64),
    seed=st.integers(0, 99),
)
def test_bptree_matches_dict_oracle(n, degree, seed):
    rng = np.random.default_rng(seed)
    keys = np.sort(rng.choice(np.arange(10 * n, dtype=np.uint64), n, replace=False))
    vals = rng.integers(0, 2**60, n).astype(np.uint64)
    oracle = dict(zip(keys.tolist(), vals.tolist()))
    dev = MemDevice()
    t = BPTree(dev, "/t.db", degree=degree)
    t.bulk_load(keys, vals)
    # point lookups
    for k in list(oracle)[:20]:
        assert t.search(int(k)) == oracle[k]
    assert t.search(int(keys[-1]) + 1) is None
    # range scan
    lo, hi = int(keys[0]), int(keys[min(n - 1, n // 2)])
    expect = sorted((k, v) for k, v in oracle.items() if lo <= k <= hi)
    assert t.scan(lo, hi) == expect
    # cold pointer-chase equals cached search
    for k in list(oracle)[:5]:
        assert t.search_cold(int(k)) == oracle[k]


def test_bptree_reopen():
    dev = MemDevice()
    keys = np.arange(100, dtype=np.uint64) * 3
    vals = keys + 7
    BPTree(dev, "/t.db", degree=16).bulk_load(keys, vals)
    t2 = BPTree(dev, "/t.db").open()
    assert t2.degree == 16
    assert t2.search(30) == 37
    assert len(t2.scan(0, 500)) == 100


def test_bptree_foreactor_scan_load_equivalence():
    dev = MemDevice()
    keys = np.arange(3000, dtype=np.uint64) * 2
    vals = keys * 5 + 1
    fa = Foreactor(device=dev, backend="io_uring", depth=16)
    plugins.register_all(fa)
    # load under speculation
    t = BPTree(dev, "/fa.db", degree=50)
    load = fa.wrap("bptree_load", plugins.capture_bptree_load)(plugins.load_with_graph)
    load(t, keys, vals)
    # verify against a plain-device reopen
    t2 = BPTree(dev, "/fa.db").open()
    scan = fa.wrap("bptree_scan", plugins.capture_bptree_scan)(plugins.scan_with_graph)
    got = scan(t2, 100, 3000)
    expect = [(int(k), int(v)) for k, v in zip(keys, vals) if 100 <= k <= 3000]
    assert got == expect
    fa.shutdown()


# -- LSM -------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), limit=st.sampled_from([1 << 12, 1 << 14]))
def test_lsm_matches_dict_oracle(seed, limit):
    rng = np.random.default_rng(seed)
    dev = MemDevice()
    lsm = LSMTree(dev, "/db", memtable_limit_bytes=limit, l0_limit=3,
                  fsync_writes=False)
    oracle = {}
    for i in range(1200):
        k = int(rng.integers(0, 300))
        if rng.random() < 0.1:
            lsm.delete(k)
            oracle[k] = None
        else:
            v = f"v{k}_{i}".encode()
            lsm.put(k, v)
            oracle[k] = v
    for k in list(oracle)[:100]:
        assert lsm.get(k) == oracle[k], k
    assert lsm.get(10**9) is None


def test_lsm_compaction_preserves_newest():
    dev = MemDevice()
    lsm = LSMTree(dev, "/db", memtable_limit_bytes=1 << 10, l0_limit=2,
                  fsync_writes=False)
    for round_ in range(5):
        for k in range(50):
            lsm.put(k, f"r{round_}k{k}".encode())
    lsm.flush()
    assert lsm.get(17) == b"r4k17"
    assert lsm.table_count() > 0


def test_lsm_reopen_from_manifest():
    dev = MemDevice()
    lsm = LSMTree(dev, "/db", memtable_limit_bytes=1 << 10, fsync_writes=False)
    for k in range(200):
        lsm.put(k, bytes([k % 251]) * 8)
    lsm.flush()
    lsm2 = LSMTree.open_existing(dev, "/db")
    for k in (0, 57, 199):
        assert lsm2.get(k) == bytes([k % 251]) * 8


def test_lsm_get_foreactor_equivalence():
    rng = np.random.default_rng(2)
    dev = MemDevice()
    lsm = LSMTree(dev, "/db", memtable_limit_bytes=1 << 12, l0_limit=50,
                  fsync_writes=False)
    ref = {}
    for k in rng.permutation(500):
        v = f"val{k}".encode()
        lsm.put(int(k), v)
        ref[int(k)] = v
    lsm.flush()
    fa = Foreactor(device=dev, backend="io_uring", depth=16)
    plugins.register_all(fa)
    get = fa.wrap("lsm_get", plugins.capture_lsm_get)(lambda l, k: l.get(k))
    for k in rng.choice(500, 60):
        assert get(lsm, int(k)) == ref[int(k)]
    assert get(lsm, 10**7) is None  # full-chain miss
    fa.shutdown()


# -- file utilities -----------------------------------------------------------------
def test_du_cp_equivalence():
    dev = MemDevice()
    for i in range(12):
        fd = dev.open(f"/dir/f{i}", "w")
        dev.pwrite(fd, b"x" * (i * 100 + 1), 0)
        dev.close(fd)
    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    plugins.register_all(fa)
    du = fa.wrap("du", plugins.capture_du)(du_dir)
    assert du(dev, "/dir") == du_dir(dev, "/dir")
    src_data = bytes(np.random.default_rng(0).integers(0, 256, 300000, dtype=np.uint8))
    fd = dev.open("/src", "w"); dev.pwrite(fd, src_data, 0); dev.close(fd)
    cp = fa.wrap("cp", plugins.capture_cp)(cp_file)
    cp(dev, "/src", "/dst", 32 * 1024)
    fd = dev.open("/dst", "r")
    assert dev.pread(fd, len(src_data), 0) == src_data
    fa.shutdown()
