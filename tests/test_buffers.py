"""Registered buffer pool: size classes, lease lifecycle, recycling, and
end-to-end integration with the I/O plane (leased reads are byte-identical
to the classic allocate-per-request path, leases are recycled at session
teardown, and wasted speculative reads recycle without ever materializing).
"""

import pytest

from repro.core import (BufferPool, Foreactor, GraphBuilder, MemDevice,
                        QueuePairBackend, Sys, io)
from repro.core.buffers import size_class
from repro.core.syscalls import IORequest, perform


# -- unit: size classes and lease lifecycle -----------------------------------

def test_size_class_boundaries():
    assert size_class(1) == 9          # everything tiny rides the 512 B class
    assert size_class(512) == 9
    assert size_class(513) == 10
    assert size_class(1 << 22) == 22   # top class: 4 MiB
    assert size_class((1 << 22) + 1) is None
    assert size_class(0) is None


def test_lease_recycles_through_the_pool():
    pool = BufferPool()
    a = pool.lease(1000)
    assert len(a.buf) == 1024
    a.mv[:4] = b"abcd"
    a.filled(4)
    assert a.to_bytes() == b"abcd"
    a.release()
    a.release()  # idempotent
    b = pool.lease(600)  # same class: must reuse the returned buffer
    assert b.buf is a.buf
    snap = pool.snapshot()
    assert snap["leases"] == 2
    assert snap["recycle_hits"] == 1
    assert snap["grows"] == 1
    assert snap["released"] == 1


def test_pool_capacity_declines_instead_of_blocking():
    pool = BufferPool(capacity_bytes=2048)
    a = pool.lease(1024)
    b = pool.lease(1024)
    assert a is not None and b is not None
    assert pool.lease(1024) is None  # registered budget exhausted
    assert pool.snapshot()["declined"] == 1
    a.release()
    assert pool.lease(1024) is not None  # freed capacity is reusable


def test_leased_pread_short_read_matches_device():
    dev = MemDevice()
    fd = dev.open("/f", "w")
    dev.pwrite(fd, b"0123456789", 0)
    pool = BufferPool()
    req = IORequest(sc=Sys.PREAD, args=(fd, 64, 4))
    req.lease = pool.lease(64)
    result = perform(dev, req)
    req.finish(result)
    assert req.take_result() == b"456789"  # short read: filled prefix only
    assert req.take_result() is req.take_result()  # materialized once


# -- integration: the plane leases, the engine recycles -----------------------

def _chain_graph(n, size):
    b = GraphBuilder("leases")
    prev = None
    for i in range(n):
        b.AddSyscallNode(f"s{i}", Sys.PREAD,
                         lambda ctx, ep, i=i: ((ctx["fd"], size, i * size),
                                               False))
        if prev is not None:
            b.SyscallSetNext(prev, f"s{i}", weak=True)
        prev = f"s{i}"
    b.SyscallSetNext(prev, None, weak=True)
    return b.Build()


def _run(n=8, size=1024, exit_at=None, calls=1):
    exit_at = n if exit_at is None else exit_at
    dev = MemDevice()
    fd = dev.open("/f", "w")
    dev.pwrite(fd, bytes(range(256)) * ((n * size) // 256 + 1), 0)
    dev.close(fd)
    fa = Foreactor(device=dev, backend="io_uring", depth=n, workers=4)
    fa.register("leases", lambda: _chain_graph(n, size))
    rfd = dev.open("/f", "r")
    results = []

    @fa.wrap("leases", lambda: {"fd": rfd})
    def prog():
        for i in range(exit_at):
            results.append(io.pread(dev, rfd, size, i * size))

    pools = []
    for _ in range(calls):
        prog()
    sess_backend = fa._backend_pool.backend  # per-thread plane
    pools.append(sess_backend.pool)
    stats = fa.total_stats
    fa.shutdown()
    expected = [bytes(dev._files["/f"][i * size:(i + 1) * size])
                for i in range(exit_at)] * calls
    return results, expected, pools[0].snapshot(), stats


def test_leased_reads_byte_identical_and_recycled():
    results, expected, pool, stats = _run()
    assert results == expected
    assert all(isinstance(r, bytes) for r in results)
    assert pool["leases"] >= stats.pre_issued > 0
    # every lease went back to the pool at session teardown
    assert pool["released"] == pool["leases"]


def test_wasted_speculation_recycles_without_allocating():
    """Early exit: the speculated tail reads complete (or cancel) unread;
    their leases recycle and a second session reuses the same registered
    memory — zero steady-state allocation for wasted reads."""
    results, expected, pool, stats = _run(n=12, exit_at=3, calls=6)
    assert results == expected
    assert stats.cancelled + stats.wasted_completions > 0
    assert pool["released"] == pool["leases"]
    # steady state: after the first call warmed the pool, later sessions'
    # leases are recycle hits, not fresh registrations
    assert pool["recycle_hits"] > 0
    assert pool["registered_bytes"] <= 12 * 1024 * 2


def test_sync_backend_takes_no_pool():
    fa = Foreactor(device=MemDevice(), backend="sync")
    fa.register("leases", lambda: _chain_graph(2, 64))
    sess = fa.activate("leases", {"fd": 0})
    try:
        assert sess.backend.pool is None  # the conformance oracle stays pure
    finally:
        fa.deactivate(sess)
        fa.shutdown()


# -- per-tenant budgets (multi-tenant serving) --------------------------------

def test_tenant_budget_charges_and_refunds():
    pool = BufferPool(capacity_bytes=1 << 20, tenant_budget_bytes=2048)
    a = pool.lease(1000, tenant="t0")  # 1 KiB class
    assert a is not None and pool.charged_bytes("t0") == 1024
    b = pool.lease(1000, tenant="t0")
    assert b is not None and pool.charged_bytes("t0") == 2048
    # at budget: declined before the free lists are even consulted
    assert pool.lease(512, tenant="t0") is None
    snap = pool.snapshot()
    assert snap["budget_declines"] == 1
    a.release()
    assert pool.charged_bytes("t0") == 1024  # refund at release
    assert pool.lease(512, tenant="t0") is not None  # back under budget
    b.release()


def test_over_budget_tenant_cannot_steal_other_tenants_buffers():
    """A tenant at its budget falls back to allocate-per-request; the
    recycled free-list buffers stay available to everyone else."""
    pool = BufferPool(capacity_bytes=1 << 20, tenant_budget_bytes=1024)
    warm = pool.lease(1024, tenant="victim")
    warm.release()  # one warm 1 KiB buffer on the free list
    hog = pool.lease(1024, tenant="hog")  # hog is now at its budget
    assert hog is not None
    assert pool.lease(1024, tenant="hog") is None  # over budget: declined
    # the decline must not have consumed the free list: the victim's next
    # lease is a recycle hit on the warm buffer
    before = pool.snapshot()["recycle_hits"]
    got = pool.lease(1024, tenant="victim")
    assert got is not None
    assert pool.snapshot()["recycle_hits"] >= before
    assert pool.charged_bytes("victim") == 1024
    got.release()
    hog.release()


def test_untenanted_leases_are_never_budget_limited():
    pool = BufferPool(capacity_bytes=1 << 20, tenant_budget_bytes=512)
    leases = [pool.lease(512) for _ in range(8)]  # 8x the tenant budget
    assert all(l is not None for l in leases)
    assert pool.snapshot()["budget_declines"] == 0
    assert pool.snapshot()["tenants_charged"] == 0
    for l in leases:
        l.release()


def test_tenant_budget_released_fully_at_session_finish():
    """End to end through the shared backend: a tenant session's leased
    reads charge its budget while in flight, and the charge refunds to
    zero at session teardown (leases release strictly after the drain)."""
    dev = MemDevice()
    fd = dev.open("/f", "w")
    dev.pwrite(fd, bytes(range(256)) * 33, 0)
    dev.close(fd)
    fa = Foreactor(device=dev, backend="io_uring", depth=8, workers=4,
                   shared=True)
    fa.register("leases", lambda: _chain_graph(8, 1024))
    rfd = dev.open("/f", "r")
    with fa.tenant("charged-tenant"):
        @fa.wrap("leases", lambda: {"fd": rfd})
        def prog():
            for i in range(8):
                io.pread(dev, rfd, 1024, i * 1024)
        prog()
        prog()
    pool = fa.shared_backend().pool
    assert pool.leases > 0, "shared plane never leased a buffer"
    assert pool.charged_bytes("charged-tenant") == 0
    assert pool.snapshot()["tenants_charged"] == 0
    assert pool.snapshot()["released"] == pool.leases
    fa.shutdown()


def test_oversized_reads_fall_back_to_classic_path():
    dev = MemDevice()
    fd = dev.open("/big", "w")
    dev.pwrite(fd, b"z" * 16, (5 << 22) - 16)
    dev.close(fd)
    backend = QueuePairBackend(dev, workers=2)
    rfd = dev.open("/big", "r")
    req = IORequest(sc=Sys.PREAD, args=(rfd, 5 << 22, 0))
    backend.submit([req])
    backend.drain()
    assert req.lease is None  # above the top size class: unleased
    out = req.take_result()
    assert len(out) == 5 << 22 and out.endswith(b"z" * 16)
    backend.shutdown()
