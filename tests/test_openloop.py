"""Deterministic open-loop scheduler harness (the 10k-session-scale test).

The open-loop benchmark (benchmarks/bench_openloop.py) drives the shared
backend with wall-clock Poisson arrivals and 32 real server threads — great
for measuring the saturation knee, useless as a CI regression test (timing
noise, sleeps, machine-dependent capacity).  This module replays the *same
seeded arrival traces* (:func:`repro.launch.ioserver.arrival_schedule`)
through the *same scheduler machinery* (``SlotScheduler`` +
``SharedBackend`` views + the completion pool) with:

* a :class:`ManualPlane` — an :class:`repro.core.backends.IOPlane` with no
  worker threads: admitted requests queue on a deque and execute only when
  the harness pumps them (a pump is "a worker ran"), demand-promoted chains
  execute inline (they outrank everything, so a real pool would run them
  next anyway);
* a :class:`FakeClock` — virtual time only advances at arrivals, so the
  trace replays identically on every run;
* a seeded interleaver — each step either admits the next arrival (a fresh
  tenant session) or advances one live session by one intercept, with
  pumps in between.  Thousands of sessions are genuinely concurrent
  (attached, holding slots, mid-graph) on a single thread.

Zero wall-clock sleeps anywhere; every schedule decision comes from one
``random.Random(seed)``.  The invariants checked at drain are the ones the
O(1) admission path and the pooled completion primitive must preserve at
scale: no deadlock, ``max_spec_inflight <= capacity``, zero leaked slots,
zero leaked tenants (the deferred-reap path), byte-correct results, and
the session-stats ledger
``pre_issued == served_async + cancelled + wasted_completions``.

Also here: the open-loop utility units (arrival schedule determinism, the
in-flight +1/-1 sweep, the fake clock) and the isolated-mode thread-budget
regression tests (the old code hard-coded 8 workers per client — 64
clients would have spawned 512 threads).
"""

import random

import pytest

from repro.core import MemDevice
from repro.core.backends import IOPlane, SharedBackend, SlotScheduler
from repro.core.engine import SessionStats, SpecSession
from repro.core.patterns import build_pread_extents_graph
from repro.core.syscalls import ReqState, Sys, perform
from repro.launch.ioserver import (ISOLATED_THREAD_BUDGET, FakeClock,
                                   arrival_schedule, isolated_workers,
                                   make_foreactor, max_inflight)


# -- open-loop utility units --------------------------------------------------

def test_arrival_schedule_is_deterministic_and_well_formed():
    a = arrival_schedule(64, 0.5, 2.0, seed=11)
    b = arrival_schedule(64, 0.5, 2.0, seed=11)
    assert a == b, "same seed must replay the identical trace"
    assert a != arrival_schedule(64, 0.5, 2.0, seed=12)
    times = [t for t, _ in a]
    assert times == sorted(times)
    assert all(0.0 <= t < 2.0 for t in times)
    assert [i for _, i in a] == list(range(len(a)))  # sequential session ids
    # superposition: 64 sessions at 0.5/s for 2s ~ 64 arrivals (Poisson)
    assert 20 <= len(a) <= 140


def test_arrival_schedule_zero_rate_is_empty():
    assert arrival_schedule(0, 1.0, 5.0) == []
    assert arrival_schedule(10, 0.0, 5.0) == []


def test_fake_clock_never_goes_backwards():
    c = FakeClock()
    c.advance_to(1.5)
    c.advance_to(0.5)  # stale arrival timestamp: ignored
    assert c.now() == 1.5
    c.advance_to(2.0)
    assert c.now() == 2.0


def test_max_inflight_counts_overlap_not_touching_sessions():
    # [0,2) and [2,4) touch but never overlap; [1,3) overlaps both
    assert max_inflight([(0, 2), (2, 4)]) == 1
    assert max_inflight([(0, 2), (2, 4), (1, 3)]) == 2
    assert max_inflight([(0, 10), (1, 9), (2, 8)]) == 3
    assert max_inflight([]) == 0


# -- isolated-mode thread-budget regression -----------------------------------

def test_isolated_workers_keeps_the_historical_8_client_shape():
    assert isolated_workers(8) == 8  # 8 clients x 8 = the original 64


def test_isolated_workers_never_oversubscribes():
    """The regression: at 64 clients the old per-client constant would have
    spawned 512 worker threads.  The budget split keeps the total near
    ISOLATED_THREAD_BUDGET (the [2,8] clamp allows a small floor excess)."""
    for clients in (1, 2, 4, 8, 16, 32, 64, 128, 256):
        per = isolated_workers(clients)
        assert 2 <= per <= 8
        assert clients * per <= max(ISOLATED_THREAD_BUDGET, 2 * clients), \
            f"{clients} clients x {per} workers oversubscribes"


def test_make_foreactor_isolated_scales_workers_with_clients():
    fa = make_foreactor("isolated", MemDevice(), clients=64)
    try:
        assert fa.workers == isolated_workers(64) == 2
    finally:
        fa.shutdown()


# -- the deterministic scheduler harness --------------------------------------

class ManualPlane(IOPlane):
    """A zero-thread I/O plane: admitted requests queue until the harness
    pumps them; demand-promoted requests (priority stamped past
    ``SharedBackend.DEMAND_BOOST``) execute inline — a real worker pool
    would run them next regardless, they outrank every queued entry."""

    def __init__(self, device):
        super().__init__(device, lanes=())
        self.pending = []
        self.executed = 0

    def _run(self, req) -> None:
        if req.claim():  # skips cancelled/evicted/already-run entries
            req.finish(perform(self.device, req))
            self.executed += 1

    def submit(self, batch):
        if not batch:
            return 0
        with self._lock:
            self._submitted.extend(batch)
            if len(self._submitted) > self._LEDGER_COMPACT:
                self._submitted = [r for r in self._submitted
                                   if not r.is_done()]
        for r in batch:
            if r.priority >= SharedBackend.DEMAND_BOOST:
                self._run(r)
            else:
                self.pending.append(r)
        return len(batch)

    # IOPlane aliases submit_batch at class definition time; the subclass
    # must re-alias or SharedBackend views would bypass the override.
    submit_batch = submit

    def pump(self, k=None) -> int:
        """Run up to ``k`` queued requests (all of them when None) — the
        harness's stand-in for worker-pool progress."""
        n = 0
        while self.pending and (k is None or n < k):
            self._run(self.pending.pop(0))
            n += 1
        return n


class ManualView(SharedBackend):
    """A SharedBackend view safe to demand-wait on a single thread: a
    frontier request that is admitted but still queued on the manual plane
    runs inline instead of blocking on a worker that does not exist.
    (Deferred chains take the normal promotion path; evicted requests take
    the normal serve-as-demand recovery path.)"""

    def wait(self, req):
        with self._lock:
            deferred = any(req in chain for chain in self._deferred)
        if not deferred and not req.is_done() \
                and req.state is ReqState.PREPARED:
            self.inner._run(req)
        return super().wait(req)


def _make_files(dev, n=16, size=64):
    out = []
    for i in range(n):
        fd = dev.open(f"/o/f{i}", "w")
        payload = bytes([(i * 7 + 3) % 251]) * size
        dev.pwrite(fd, payload, 0)
        dev.close(fd)
        out.append((dev.open(f"/o/f{i}", "r"), size, 0, payload))
    return out


def _session_steps(idx, rng_seed, plane, sched, dev, graph, files, results):
    """Generator: one open-loop session, one intercept per step.  Created
    lazily — the view attaches (and the tenant appears in the scheduler)
    at the first step, exactly like an arrival."""
    rng = random.Random(rng_seed)
    k = rng.randrange(2, len(files) + 1)
    extents = rng.sample(files, k)
    stop_at = rng.randrange(len(extents))  # early exit: leftover speculation
    view = ManualView(plane, sched, tenant=f"s{idx}",
                      weight=1.0 + (idx % 3),
                      priority=("low", "normal", "high")[idx % 3])
    sess = SpecSession(graph, {"extents": [e[:3] for e in extents]},
                       view, dev, depth=4)
    try:
        for j, (fd, n, off, payload) in enumerate(extents):
            data = sess.intercept(Sys.PREAD, (fd, n, off))
            assert data == payload, f"session {idx} read corrupt bytes"
            if j == stop_at:
                break
            yield
    finally:
        stats = sess.finish()
        view.shutdown()
        results.append(stats)


def run_trace(sessions, rate, duration, capacity=12, seed=0,
              arrival_bias=0.85):
    """Replay one seeded arrival trace through the shared scheduler on a
    single thread and return the merged report.  ``arrival_bias`` is the
    probability a step admits the next arrival instead of advancing a live
    session — high bias piles sessions up, which is the point."""
    dev = MemDevice()
    files = _make_files(dev)
    plane = ManualPlane(dev)
    sched = SlotScheduler(capacity)
    graph = build_pread_extents_graph("openloop_scan", weak=True)
    schedule = arrival_schedule(sessions, rate, duration, seed=seed)
    assert schedule, "empty trace: nothing to test"
    clock = FakeClock()
    rng = random.Random(seed)
    results = []

    live = []  # (generator, arrival_s)
    events = []  # (arrival_s, completion_s) in virtual time
    peak_live = 0
    ai = 0
    while ai < len(schedule) or live:
        if ai < len(schedule) and (not live or rng.random() < arrival_bias):
            t_arr, idx = schedule[ai]
            ai += 1
            clock.advance_to(t_arr)
            g = _session_steps(idx, seed * 1000003 + idx, plane, sched,
                               dev, graph, files, results)
            try:
                next(g)  # first intercept: the session is now live
            except StopIteration:  # single-read session: done on arrival
                events.append((t_arr, clock.now()))
            else:
                live.append((g, t_arr))
                peak_live = max(peak_live, len(live))
        else:
            plane.pump(rng.randrange(0, 3))  # some worker progress
            i = rng.randrange(len(live))
            g, t_arr = live[i]
            try:
                next(g)
            except StopIteration:
                live.pop(i)
                events.append((t_arr, clock.now()))
    plane.pump()  # drain whatever speculation outlived its session

    total = SessionStats()
    for s in results:
        total.merge(s)
    return {
        "arrivals": len(schedule),
        "finished": len(results),
        "peak_live": peak_live,
        "max_inflight_virtual": max_inflight(events),
        "stats": total,
        "scheduler": sched.snapshot(),
        "plane": plane,
    }


def _check_invariants(rep):
    assert rep["finished"] == rep["arrivals"], "a session never finished"
    snap = rep["scheduler"]
    # fairness: demand never queues behind more speculation than capacity
    assert snap["max_spec_inflight"] <= snap["capacity"], snap
    # every admitted slot was released exactly once (completion callback)
    assert snap["spec_inflight"] == 0, snap
    # the deferred-reap path: no tenant state outlives its sessions
    assert snap["tenants"] == 0, snap
    s = rep["stats"]
    assert s.pre_issued == \
        s.served_async + s.cancelled + s.wasted_completions, vars(s)
    assert s.served_async > 0, "speculation never overlapped anything"
    assert snap["admitted"] > 0 and snap["evictions"] >= 0
    # the final pump drained the plane: nothing queued, nothing leaked
    assert not rep["plane"].pending
    assert rep["plane"].inflight() == 0


def test_scheduler_harness_small_trace_tier1():
    """Tier-1 size: ~128 arrivals, every invariant at drain."""
    rep = run_trace(sessions=128, rate=1.0, duration=1.0, capacity=12,
                    seed=3)
    assert rep["arrivals"] >= 64
    assert rep["peak_live"] >= 32, "interleaver never built concurrency"
    _check_invariants(rep)


def test_scheduler_harness_replays_identically():
    """The whole point of the fake clock + seeded interleaver: the same
    seed produces the same admissions, evictions, and stats — bit for
    bit."""
    a = run_trace(sessions=64, rate=1.0, duration=1.0, capacity=8, seed=9)
    b = run_trace(sessions=64, rate=1.0, duration=1.0, capacity=8, seed=9)
    assert a["scheduler"] == b["scheduler"]
    counts = ("intercepted", "pre_issued", "submits", "served_async",
              "served_sync", "cancelled", "wasted_completions")
    assert {f: getattr(a["stats"], f) for f in counts} == \
           {f: getattr(b["stats"], f) for f in counts}
    assert a["peak_live"] == b["peak_live"]
    assert a["max_inflight_virtual"] == b["max_inflight_virtual"]


def test_scheduler_harness_tiny_capacity_still_drains():
    """capacity=1 degenerates to demand-at-a-time with constant eviction
    pressure — the harshest admission/eviction interleaving."""
    rep = run_trace(sessions=48, rate=1.0, duration=1.0, capacity=1, seed=5)
    snap = rep["scheduler"]
    assert rep["finished"] == rep["arrivals"]
    assert snap["max_spec_inflight"] <= 1
    assert snap["spec_inflight"] == 0 and snap["tenants"] == 0
    s = rep["stats"]
    assert s.pre_issued == \
        s.served_async + s.cancelled + s.wasted_completions


@pytest.mark.stress
def test_scheduler_harness_1k_sessions():
    """The scale the O(1) admission path exists for: 1k+ concurrent tenant
    sessions on one shared backend, single-threaded, zero sleeps."""
    rep = run_trace(sessions=1024, rate=1.2, duration=1.0, capacity=24,
                    seed=7, arrival_bias=0.9)
    assert rep["arrivals"] >= 1000
    assert rep["peak_live"] >= 1000, \
        f"wanted 1k+ concurrent sessions, peaked at {rep['peak_live']}"
    _check_invariants(rep)
