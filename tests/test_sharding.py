"""Sharding-rule tests: every param/cache/batch spec must be valid
(divisible, axis-unique) for every arch on the production meshes — checked
against AbstractMesh so no 512-device runtime is needed."""

import jax
import jax.numpy as jnp
import pytest

try:
    from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P
except ImportError:  # AxisType landed in jax 0.5; skip on older toolchains
    pytest.skip("jax.sharding.AxisType unavailable in this jax version",
                allow_module_level=True)

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch import sharding as shd
from repro.models import build_model

MESH_1POD = AbstractMesh((16, 16), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
MESH_2POD = AbstractMesh((2, 16, 16), ("pod", "data", "model"),
                         axis_types=(AxisType.Auto,) * 3)


def _axis_sz(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _check_tree(tree, specs, mesh):
    leaves = jax.tree.leaves(tree)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        assert isinstance(spec, P)
        used = []
        assert len(spec) <= len(leaf.shape)
        for d, axis in enumerate(spec):
            if axis is None:
                continue
            names = axis if isinstance(axis, tuple) else (axis,)
            for nm in names:
                assert nm not in used, (spec, leaf.shape)
                used.append(nm)
            assert leaf.shape[d] % _axis_sz(mesh, axis) == 0, \
                (spec, leaf.shape, d)


@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD], ids=["1pod", "2pod"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_valid_all_archs(arch, mesh):
    cfg = get_config(arch)  # FULL config — shapes must divide for real dims
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(params_sds, mesh)
    _check_tree(params_sds, specs, mesh)


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "deepseek_v2_236b",
                                  "gemma_2b", "zamba2_1_2b", "rwkv6_7b"])
def test_cache_specs_valid(arch):
    from repro.models import lm

    cfg = get_config(arch)
    cache_sds = jax.eval_shape(lambda: lm.init_cache(cfg, 128, 1024))
    specs = shd.cache_specs(cache_sds, MESH_1POD)
    _check_tree(cache_sds, specs, MESH_1POD)


def test_model_axis_engaged_for_key_tensors():
    """TP sanity: tinyllama q heads (32) shard over model=16, kv (4) do
    not; granite experts (40) fall back to TP-within-expert."""
    cfg = get_config("tinyllama_1_1b")
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(sds, MESH_1POD)
    def at(spec, shape_len, negdim):
        t = tuple(spec) + (None,) * (shape_len - len(tuple(spec)))
        return t[negdim]

    wq = specs["layers"][0]["attn"]["wq"]
    assert at(wq, 4, -2) == "model"    # 32 q heads sharded (stacked: 4 dims)
    wk = specs["layers"][0]["attn"]["wk"]
    assert at(wk, 4, -2) is None       # 4 kv heads not divisible
    g = get_config("granite_moe_3b_a800m")
    gm = build_model(g)
    gsds = jax.eval_shape(gm.init, jax.random.PRNGKey(0))
    gspecs = shd.param_specs(gsds, MESH_1POD)
    # granite: 40 experts % 16 != 0 -> expert dim unsharded, F dim takes model
    layer = gspecs["layers"][0]["ffn"]
    assert at(layer["wi"], 4, -3) is None and at(layer["wi"], 4, -1) == "model"


def test_batch_spec_fallback_chain():
    spec = shd.batch_specs({"tokens": jax.ShapeDtypeStruct((256, 128), jnp.int32)},
                           MESH_1POD, profile="fsdp")
    assert spec["tokens"][0] == ("data", "model")  # 256 over all 256
    spec = shd.batch_specs({"tokens": jax.ShapeDtypeStruct((128, 8), jnp.int32)},
                           MESH_1POD, profile="fsdp")
    assert spec["tokens"][0] == "data"  # 128 % 256 != 0 -> data only
    spec = shd.batch_specs({"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)},
                           MESH_1POD, profile="fsdp")
    assert spec["tokens"][0] is None  # batch 1: replicate


def test_embed_not_fsdp_sharded_on_dmodel():
    """Regression: sharding the embedding's d_model over "data" made XLA
    psum (B,C,V) logits chunks — ~190 GiB/device (EXPERIMENTS §Perf it.1)."""
    cfg = get_config("gemma_2b")
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_specs(sds, MESH_1POD)
    emb = tuple(specs["embed"]["tok"])
    assert emb[0] == "model" and (len(emb) < 2 or emb[1] is None)
