"""Pre-issuing engine tests (paper §5.2 Alg. 1, §5.3 correctness rules)."""

import threading

import pytest
from _hypothesis_support import given, settings, st

from repro.core import (Foreactor, GraphBuilder, MemDevice, SpecSession, Sys, io)
from repro.core.graph import FromNode


def make_dev(nfiles=30, size=64):
    dev = MemDevice()
    for i in range(nfiles):
        fd = dev.open(f"/d/f{i}", "w")
        dev.pwrite(fd, bytes([i % 251]) * size, 0)
        dev.close(fd)
    return dev


def stat_loop_graph():
    b = GraphBuilder("stat_loop")
    b.AddSyscallNode(
        "fstat", Sys.FSTATAT,
        lambda ctx, ep: ((ctx["paths"][ep[0]],), False)
        if ep[0] < len(ctx["paths"]) else None)
    b.AddBranchingNode("more", lambda ctx, ep: 0 if ep[0] + 1 < len(ctx["paths"]) else 1)
    b.SyscallSetNext("fstat", "more")
    b.BranchAppendChild("more", "fstat", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def read_chain_weak_graph():
    """LSM-shaped: pure reads with weak edges (early exit possible)."""
    b = GraphBuilder("read_chain")
    b.AddSyscallNode(
        "pread", Sys.PREAD,
        lambda ctx, ep: (tuple(ctx["extents"][ep[0]]), False)
        if ep[0] < len(ctx["extents"]) else None)
    b.AddBranchingNode("more", lambda ctx, ep: 0 if ep[0] + 1 < len(ctx["extents"]) else 1)
    b.SyscallSetNext("pread", "more", weak=True)
    b.BranchAppendChild("more", "pread", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def write_loop_graph():
    b = GraphBuilder("write_loop")
    b.AddSyscallNode(
        "pwrite", Sys.PWRITE,
        lambda ctx, ep: ((ctx["fd"], ctx["chunks"][ep[0]], ep[0] * len(ctx["chunks"][0])), False)
        if ep[0] < len(ctx["chunks"]) else None)
    b.AddBranchingNode("more", lambda ctx, ep: 0 if ep[0] + 1 < len(ctx["chunks"]) else 1)
    b.SyscallSetNext("pwrite", "more")
    b.BranchAppendChild("more", "pwrite", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


def weak_write_graph():
    """A weak edge ahead of a pwrite — the pwrite must NOT be pre-issued."""
    b = GraphBuilder("weak_write")
    b.AddSyscallNode("pread", Sys.PREAD, lambda ctx, ep: ((ctx["rfd"], 8, 0), False))
    b.AddSyscallNode("pwrite", Sys.PWRITE, lambda ctx, ep: ((ctx["wfd"], b"Z" * 8, 0), False))
    b.SyscallSetNext("pread", "pwrite", weak=True)
    b.SyscallSetNext("pwrite", None)
    return b.Build()


@pytest.mark.parametrize("backend", ["io_uring", "user_threads"])
def test_external_synchrony_stat_loop(backend):
    """Speculated execution must be indistinguishable from serial (§5.3)."""
    dev = make_dev()
    paths = [f"/d/f{i}" for i in range(30)]
    fa = Foreactor(device=dev, backend=backend, depth=8)
    fa.register("stat_loop", stat_loop_graph)

    @fa.wrap("stat_loop", lambda paths: {"paths": paths})
    def du(paths):
        return sum(io.fstatat(dev, p).st_size for p in paths)

    serial = sum(io.fstatat(dev, p).st_size for p in paths)
    assert du(paths) == serial
    assert fa.total_stats.served_async > 0
    fa.shutdown()


def test_weak_edge_blocks_nonpure():
    """The paper's §3.3 rule, with staging off: a non-pure syscall behind a
    weak edge is never pre-issued."""
    dev = make_dev(2)
    rfd = dev.open("/d/f0", "r")
    wfd = dev.open("/w.out", "w")
    fa = Foreactor(device=dev, backend="io_uring", depth=8, staging=False)
    fa.register("weak_write", weak_write_graph)

    @fa.wrap("weak_write", lambda: {"rfd": rfd, "wfd": wfd})
    def f_early_exit():
        io.pread(dev, rfd, 8, 0)
        return "early"  # never issues the pwrite

    f_early_exit()
    # the pwrite was NOT pre-issued: /w.out must still be empty
    assert dev.fstatat("/w.out").st_size == 0
    assert fa.total_stats.pre_issued == 0  # nothing beyond the weak edge
    fa.shutdown()


def test_weak_edge_write_speculates_with_staging():
    """With staging on (the default), the same weak-edge pwrite IS
    pre-issued — as an undoable staged overwrite — and rolled back when the
    early exit abandons it: identical committed state, one step more
    overlap available."""
    dev = make_dev(2)
    rfd = dev.open("/d/f0", "r")
    wfd = dev.open("/w.out", "w")
    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    fa.register("weak_write", weak_write_graph)

    @fa.wrap("weak_write", lambda: {"rfd": rfd, "wfd": wfd})
    def f_early_exit():
        io.pread(dev, rfd, 8, 0)
        return "early"  # never issues the pwrite

    f_early_exit()
    # speculated, then undone: the committed namespace shows no trace
    assert dev.fstatat("/w.out").st_size == 0
    assert fa.total_stats.pre_issued == 1  # the staged pwrite, beyond weak
    fa.shutdown()


def test_guaranteed_writes_are_preissued():
    dev = MemDevice()
    fd = dev.open("/out.bin", "w")
    chunks = [bytes([i]) * 16 for i in range(12)]
    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    fa.register("write_loop", write_loop_graph)

    @fa.wrap("write_loop", lambda: {"fd": fd, "chunks": chunks})
    def writer():
        for i, c in enumerate(chunks):
            io.pwrite(dev, fd, c, i * 16)

    writer()
    assert fa.total_stats.pre_issued > 0  # strong edges: writes speculated
    got = dev.pread(fd, 16 * 12, 0)
    assert got == b"".join(chunks)  # and the file is exactly right
    fa.shutdown()


def test_early_exit_cancels_speculation():
    dev = make_dev(20)
    fa = Foreactor(device=dev, backend="io_uring", depth=16)
    fa.register("read_chain", read_chain_weak_graph)
    extents = []
    fds = []
    for i in range(20):
        fd = dev.open(f"/d/f{i}", "r")
        fds.append(fd)
        extents.append((fd, 16, 0))

    @fa.wrap("read_chain", lambda: {"extents": extents})
    def search():
        for i, (fd, n, off) in enumerate(extents):
            data = io.pread(dev, fd, n, off)
            if i == 2:  # found early
                return data
        return None

    out = search()
    assert out == bytes([2]) * 16
    s = fa.total_stats
    # speculation beyond the early exit happened and was then discarded
    assert s.pre_issued > 3
    assert s.cancelled + s.wasted_completions > 0
    fa.shutdown()


def test_linked_pair_deferred_data():
    """Link + FromNode: pwrite consumes the linked pread's buffer."""
    dev = MemDevice()
    fd_in = dev.open("/in.bin", "w")
    dev.pwrite(fd_in, bytes(range(64)), 0)
    fd_out = dev.open("/out.bin", "w")

    def g():
        b = GraphBuilder("link")
        b.AddSyscallNode("pread", Sys.PREAD,
                         lambda ctx, ep: ((fd_in, 32, 32 * ep[0]), True))
        b.AddSyscallNode("pwrite", Sys.PWRITE,
                         lambda ctx, ep: ((fd_out, FromNode("pread"), 32 * ep[0]), False))
        b.AddBranchingNode("more", lambda ctx, ep: 0 if ep[0] < 1 else 1)
        b.SyscallSetNext("pread", "pwrite")
        b.SyscallSetNext("pwrite", "more")
        b.BranchAppendChild("more", "pread", loopback=True)
        b.BranchAppendChild("more", None)
        return b.Build()

    fa = Foreactor(device=dev, backend="io_uring", depth=6)
    fa.register("link", g)

    @fa.wrap("link", lambda: {})
    def copy2():
        for i in range(2):
            d = io.pread(dev, fd_in, 32, 32 * i)
            io.pwrite(dev, fd_out, d, 32 * i)

    copy2()
    assert dev.pread(fd_out, 64, 0) == bytes(range(64))
    fa.shutdown()


def test_untracked_syscalls_pass_through():
    dev = make_dev(3)
    fa = Foreactor(device=dev, backend="io_uring", depth=4)
    fa.register("stat_loop", stat_loop_graph)
    paths = [f"/d/f{i}" for i in range(3)]

    @fa.wrap("stat_loop", lambda paths: {"paths": paths})
    def du_with_extra(paths):
        total = 0
        for p in paths:
            total += io.fstatat(dev, p).st_size
        # not in the graph: must pass through untouched
        return total, io.getdents(dev, "/d")

    total, names = du_with_extra(paths)
    assert len(names) == 3
    assert fa.total_stats.untracked >= 1
    fa.shutdown()


def test_per_thread_sessions_are_independent():
    dev = make_dev(40)
    fa = Foreactor(device=dev, backend="io_uring", depth=8)
    fa.register("stat_loop", stat_loop_graph)
    errs = []

    def worker(lo):
        paths = [f"/d/f{i}" for i in range(lo, lo + 20)]

        @fa.wrap("stat_loop", lambda paths: {"paths": paths})
        def du(paths):
            return sum(io.fstatat(dev, p).st_size for p in paths)

        expect = sum(dev.fstatat(p).st_size for p in paths)
        if du(paths) != expect:
            errs.append(lo)

    ts = [threading.Thread(target=worker, args=(lo,)) for lo in (0, 20)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    fa.shutdown()


@settings(max_examples=20, deadline=None)
@given(depth=st.integers(0, 32), n=st.integers(1, 25),
       backend=st.sampled_from(["io_uring", "user_threads"]))
def test_property_stat_loop_any_depth(depth, n, backend):
    """External synchrony holds for any peek depth / loop length / backend."""
    dev = make_dev(n)
    paths = [f"/d/f{i}" for i in range(n)]
    fa = Foreactor(device=dev, backend=backend, depth=depth)
    fa.register("stat_loop", stat_loop_graph)

    @fa.wrap("stat_loop", lambda paths: {"paths": paths})
    def du(paths):
        return sum(io.fstatat(dev, p).st_size for p in paths)

    assert du(paths) == sum(dev.fstatat(p).st_size for p in paths)
    fa.shutdown()
