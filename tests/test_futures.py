"""Futures-style session API conformance.

``io.pread_async`` returns an :class:`repro.core.IOFuture` — a handle to a
ledgered request whose demand point moves to ``result()``.  The contract
under test:

* byte identity: any interleaving of async and blocking intercepts, with
  late out-of-order resolution, returns exactly the bytes the all-blocking
  sync run returns, on every backend × depth;
* the ledger invariant ``pre_issued == served_async + cancelled +
  wasted_completions`` holds with futures in play — including futures left
  unresolved at ``finish()`` (drained-then-materialized) and futures
  crossing a failed session (poisoned, never silently empty);
* lease lifetime: a long all-async session keeps O(inflight) registered
  buffers leased, not O(session length) — the mid-session recycling fix;
* ``LSMTree.multi_get`` (N keys, one generated ``lsm_multiget`` plan)
  matches N sequential ``get``\\ s on every backend, and one key's EIO does
  not abandon the rest of the batch.
"""

import errno

import pytest

from repro.core import (Foreactor, FuturePoisoned, GraphBuilder, IOFuture,
                        MemDevice, ShardedDevice, Sys, io)
from repro.store import plugins
from repro.store.lsm import LSMTree

N_FILES = 6
FILE_SIZE = 96

CONFIGS = [
    ("sync", "flat", dict(backend="sync")),
    ("user_threads", "flat", dict(backend="user_threads", workers=4)),
    ("io_uring", "flat", dict(backend="io_uring", workers=4)),
    ("multi_queue", "sharded", dict(backend="multi_queue", workers=2)),
    ("shared", "flat", dict(backend="io_uring", workers=4, shared=True)),
]
DEPTHS = [0, 1, "adaptive"]


def file_bytes(i: int) -> bytes:
    return bytes((i * 7 + j) % 251 for j in range(FILE_SIZE))


def make_device(kind: str = "flat"):
    dev = ShardedDevice([MemDevice() for _ in range(3)]) if kind == "sharded" \
        else MemDevice()
    for i in range(N_FILES):
        fd = dev.open(f"/c/f{i}", "w")
        dev.pwrite(fd, file_bytes(i), 0)
        dev.close(fd)
    return dev


def build_pread_chain(name: str, reads):
    """One PREAD node per (file, size, off), every edge weak — the pure
    all-pre-issuable shape the futures API targets."""
    b = GraphBuilder(name)
    prev = None
    for idx, (f, size, off) in enumerate(reads):
        def args(ctx, ep, f=f, size=size, off=off):
            return ((ctx["fds"][f], size, off), False)
        b.AddSyscallNode(f"s{idx}", Sys.PREAD, args)
        if prev is not None:
            b.SyscallSetNext(prev, f"s{idx}", weak=True)
        prev = f"s{idx}"
    b.SyscallSetNext(prev, None, weak=True)
    return b.Build()


def assert_ledger_invariant(stats):
    assert stats.pre_issued == (stats.served_async + stats.cancelled
                                + stats.wasted_completions), vars(stats)


READS = [((i * 5) % N_FILES, 8 + (i * 3) % 24, (i * 11) % (FILE_SIZE - 32))
         for i in range(12)]
EXPECTED = [file_bytes(f)[off:off + size] for f, size, off in READS]


def _run_mixed(dev, fa_kwargs, depth):
    """Even steps via pread_async (resolved late, in reverse), odd steps
    blocking — the interleaving stresses frontier advance on both paths."""
    fa = Foreactor(device=dev, depth=depth, **fa_kwargs)
    fa.register("mix", lambda: build_pread_chain("mix", READS))
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]

    @fa.wrap("mix", lambda: {"fds": fds})
    def prog():
        out = [None] * len(READS)
        futs = []
        for idx, (f, size, off) in enumerate(READS):
            if idx % 2 == 0:
                futs.append((idx, io.pread_async(dev, fds[f], size, off)))
            else:
                out[idx] = io.pread(dev, fds[f], size, off)
        for idx, fut in reversed(futs):  # late demand, out of order
            out[idx] = fut.result()
        return out

    try:
        result = prog()
    finally:
        stats = fa.total_stats
        fa.shutdown()
    return result, stats


@pytest.mark.parametrize("depth", DEPTHS)
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_futures_byte_identical_to_blocking(cfg, depth):
    _name, kind, kwargs = cfg
    result, stats = _run_mixed(make_device(kind), kwargs, depth)
    assert result == EXPECTED
    assert stats.futures_issued > 0
    assert_ledger_invariant(stats)


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_future_resolves_after_finish(cfg):
    """A future escaping its session is drained at finish() and must still
    materialize the right bytes afterwards (never a dropped lease)."""
    _name, kind, kwargs = cfg
    dev = make_device(kind)
    fa = Foreactor(device=dev, depth=4, **kwargs)
    reads = READS[:4]
    fa.register("esc", lambda: build_pread_chain("esc", reads))
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]

    @fa.wrap("esc", lambda: {"fds": fds})
    def prog():
        return [io.pread_async(dev, fds[f], size, off)
                for f, size, off in reads]

    futs = prog()
    stats = fa.total_stats
    assert stats.futures_drained == len(reads)
    assert_ledger_invariant(stats)
    for fut, want in zip(futs, EXPECTED[:4]):
        assert fut.settled
        assert fut.result() == want
    fa.shutdown()


@pytest.mark.parametrize("cfg", [CONFIGS[0], CONFIGS[2]],
                         ids=["sync", "io_uring"])
def test_future_poisoned_by_failed_session(cfg):
    """mark_failed poisons unresolved futures: result() raises
    FuturePoisoned instead of returning bytes the session disowned."""
    _name, kind, kwargs = cfg
    dev = make_device(kind)
    fa = Foreactor(device=dev, depth=4, **kwargs)
    reads = READS[:3]
    fa.register("boom", lambda: build_pread_chain("boom", reads))
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]
    escaped = []

    @fa.wrap("boom", lambda: {"fds": fds})
    def prog():
        f, size, off = reads[0]
        escaped.append(io.pread_async(dev, fds[f], size, off))
        raise RuntimeError("injected failure")

    with pytest.raises(RuntimeError, match="injected failure"):
        prog()
    (fut,) = escaped
    assert fut.settled
    with pytest.raises(FuturePoisoned):
        fut.result()
    with pytest.raises(FuturePoisoned):  # sticky, not one-shot
        fut.result()
    assert_ledger_invariant(fa.total_stats)
    fa.shutdown()


def test_unresolved_futures_ledger_accounting():
    """Futures never resolved by the caller are settled by the finish-time
    drain, each accounted exactly once in the ledger."""
    dev = make_device()
    fa = Foreactor(device=dev, backend="io_uring", workers=4, depth=4)
    fa.register("drain", lambda: build_pread_chain("drain", READS))
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]

    @fa.wrap("drain", lambda: {"fds": fds})
    def prog():
        for f, size, off in READS:
            io.pread_async(dev, fds[f], size, off)

    prog()
    stats = fa.total_stats
    assert stats.futures_issued == len(READS)
    assert stats.futures_drained == len(READS)
    assert_ledger_invariant(stats)
    fa.shutdown()


def test_pread_async_without_session_is_eager():
    """Outside any session the future comes back already resolved — the
    degenerate form sequential oracles rely on."""
    dev = make_device()
    fd = dev.open("/c/f0", "r")
    fut = io.pread_async(dev, fd, 16, 8)
    assert isinstance(fut, IOFuture)
    assert fut.settled
    assert fut.result() == file_bytes(0)[8:24]


# -- lease lifetime (the mid-session recycling fix) ---------------------------

def test_lease_recycling_bounds_pool_occupancy():
    """100 reads through one session must peak at O(inflight window)
    leased registered buffers, not O(reads): each lease is released at the
    last-consumer materialization, mid-session."""
    dev = make_device()
    n = 100
    reads = [(i % N_FILES, 16, (i * 7) % (FILE_SIZE - 16)) for i in range(n)]
    fa = Foreactor(device=dev, backend="io_uring", workers=4, depth=4)
    fa.register("long", lambda: build_pread_chain("long", reads))
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]

    @fa.wrap("long", lambda: {"fds": fds})
    def prog():
        return [io.pread(dev, fds[f], size, off) for f, size, off in reads]

    out = prog()
    assert out == [file_bytes(f)[off:off + size] for f, size, off in reads]
    backend = fa._backend_pool.backend
    pool = backend.pool
    assert pool.leased_now == 0, pool.snapshot()
    # depth-4 speculation + 4 workers: the window is ~8; 16 leaves slack
    # without ever tolerating the old O(n) leak (which peaked at 100)
    assert pool.peak_leased <= 16, pool.snapshot()
    assert_ledger_invariant(fa.total_stats)
    fa.shutdown()


def test_cancelled_deferred_future_releases_slots():
    """Regression: cancelling a future whose chain the shared scheduler had
    *deferred* must not leak speculation slots.  The request goes terminal
    in place inside the view's staging queue; when the chain was re-offered,
    admit() used to hook the slot-release callback onto the already-dead
    request — the callback never fired, and the pool starved at capacity
    (every later op demand-promoting past a permanently full budget)."""
    dev = make_device()
    reads = [(i % N_FILES, 16, (i * 8) % (FILE_SIZE - 16)) for i in range(12)]
    fa = Foreactor(device=dev, backend="io_uring", workers=4,
                   shared=True, shared_slots=4, depth=len(reads))
    fa.register("leak", lambda: build_pread_chain("leak", reads))
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]

    @fa.wrap("leak", lambda: {"fds": fds})
    def prog():
        futs = [io.pread_async(dev, fds[f], size, off)
                for f, size, off in reads]
        for fut in futs[4:]:  # tail chains the 4-slot budget deferred
            fut.cancel()
        # resolving the head re-flushes the deferred queue through admit()
        return [fut.result() for fut in futs[:4]]

    try:
        out = prog()
    finally:
        stats = fa.total_stats
        snap = fa.scheduler.snapshot()
        fa.shutdown()
    assert out == [file_bytes(f)[off:off + size] for f, size, off in reads[:4]]
    assert snap["deferred"] > 0, snap  # the scenario really deferred chains
    assert snap["spec_inflight"] == 0, snap
    assert_ledger_invariant(stats)


# -- multi_get ----------------------------------------------------------------

def _make_lsm(dev):
    """A store with several L0 tables (multi-candidate chains), memtable
    residents, tombstones, and misses — every multi_get resolution path."""
    lsm = LSMTree(dev, "/db", memtable_limit_bytes=1 << 10, l0_limit=10 ** 6,
                  fsync_writes=False)
    for k in range(120):
        lsm.put(k, f"v{k}".encode() * 3)
    lsm.flush()
    for k in range(0, 120, 3):  # second generation -> longer chains
        lsm.put(k, f"w{k}".encode() * 2)
    lsm.flush()
    for k in range(0, 120, 10):
        lsm.put(k, f"mem{k}".encode())  # memtable hits
    for k in range(5, 120, 20):
        lsm.delete(k)  # tombstones
    return lsm


QUERY = [0, 5, 7, 10, 30, 31, 64, 99, 119, 500, 17, 45]  # incl. misses


@pytest.mark.parametrize("cfg", CONFIGS, ids=[c[0] for c in CONFIGS])
def test_multi_get_matches_sequential_gets(cfg):
    _name, kind, kwargs = cfg
    dev = make_device(kind)
    lsm = _make_lsm(dev)
    oracle = [lsm.get(k) for k in QUERY]  # plain sequential, no session
    fa = Foreactor(device=dev, depth=16, **kwargs)
    plugins.register_all(fa)
    mget = fa.wrap("lsm_multiget", plugins.capture_lsm_multiget)(
        lambda l, ks: l.multi_get(ks))
    assert mget(lsm, QUERY) == oracle
    assert lsm.multi_get(QUERY) == oracle  # and sessionless
    assert_ledger_invariant(fa.total_stats)
    fa.shutdown()


class _EIODevice:
    """Delegating device wrapper: pread at a poisoned offset raises EIO."""

    def __init__(self, inner):
        self.inner = inner
        self.eio_offsets = set()

    def pread(self, fd, size, off):
        if off in self.eio_offsets:
            raise OSError(errno.EIO, f"injected EIO at offset {off}")
        return self.inner.pread(fd, size, off)

    def pread_into(self, fd, buf, off):  # the registered-buffer read path
        if off in self.eio_offsets:
            raise OSError(errno.EIO, f"injected EIO at offset {off}")
        return self.inner.pread_into(fd, buf, off)

    def __getattr__(self, name):
        return getattr(self.inner, name)


def test_multi_get_eio_on_one_key_spares_the_rest():
    """One key's read error surfaces as the batch's exception, but only
    after every other key was harvested — siblings are never abandoned."""
    dev = _EIODevice(MemDevice())
    lsm = LSMTree(dev, "/db", memtable_limit_bytes=1 << 20, l0_limit=10 ** 6,
                  fsync_writes=False)
    for k in range(64):
        # ~3 KB values: one entry per 4 KB data block, so every key owns a
        # distinct block offset and EIO can be aimed at exactly one key
        lsm.put(k, f"v{k:03d}".encode() * 600)
    lsm.flush()  # one sstable: exactly one candidate per key
    keys = list(range(0, 64, 4))  # 16 keys
    offsets = [lsm.candidates(k)[0][1] for k in keys]
    # pick a victim whose block no other queried key shares
    victim_i = next(i for i, off in enumerate(offsets)
                    if offsets.count(off) == 1)
    dev.eio_offsets = {offsets[victim_i]}
    fa = Foreactor(device=dev, backend="io_uring", workers=4, depth=16)
    plugins.register_all(fa)
    mget = fa.wrap("lsm_multiget", plugins.capture_lsm_multiget)(
        lambda l, ks: l.multi_get(ks))
    with pytest.raises(OSError) as exc:
        mget(lsm, keys)
    assert exc.value.errno == errno.EIO
    stats = fa.total_stats
    # every non-victim key's chain was still served to its caller
    assert stats.served_async >= len(keys) - 1, vars(stats)
    assert_ledger_invariant(stats)
    fa.shutdown()


def test_future_error_is_cached_and_siblings_resolve():
    """Future-level EIO: the erroring future raises on every result() call,
    and a sibling future in the same session still yields its bytes."""
    dev = _EIODevice(make_device())
    dev.eio_offsets = {40}
    fa = Foreactor(device=dev, backend="io_uring", workers=4, depth=4)
    reads = [(0, 8, 40), (1, 8, 8)]
    fa.register("eio", lambda: build_pread_chain("eio", reads))
    fds = [dev.open(f"/c/f{i}", "r") for i in range(N_FILES)]

    @fa.wrap("eio", lambda: {"fds": fds})
    def prog():
        return [io.pread_async(dev, fds[f], size, off)
                for f, size, off in reads]

    bad, good = prog()
    assert good.result() == file_bytes(1)[8:16]
    for _ in range(2):
        with pytest.raises(OSError) as exc:
            bad.result()
        assert exc.value.errno == errno.EIO
    assert_ledger_invariant(fa.total_stats)
    fa.shutdown()


# -- plan-cache / graph-version observability ---------------------------------

def test_plan_stats_present_and_monotone():
    dev = make_device()
    fa = Foreactor(device=dev, backend="sync", depth=0)
    fa.register("obs", lambda: build_pread_chain("obs", READS[:2]))
    fa.plan("obs")
    s1 = fa.plan_cache_stats()
    assert "obs" in s1["per_graph"]
    g1 = s1["per_graph"]["obs"]
    assert g1["probes"] >= 1 and g1["compiles"] >= 1
    assert g1["graph_version"] == 1
    assert s1["global"]["compiles"] >= 1
    fa.plan("obs")  # cache hit: probes up, compiles flat
    g2 = fa.plan_cache_stats()["per_graph"]["obs"]
    assert g2["probes"] == g1["probes"] + 1
    assert g2["compiles"] == g1["compiles"]
    fa.invalidate_graph("obs")  # re-mine: version bumps, plan recompiles
    fa.plan("obs")
    g3 = fa.plan_cache_stats()["per_graph"]["obs"]
    assert g3["graph_version"] == 2
    assert g3["compiles"] == g2["compiles"] + 1
    fa.shutdown()


def test_ioserver_report_surfaces_plan_stats():
    from repro.launch.ioserver import (build_store, get_clients,
                                       multiget_clients, run_serving)
    store = build_store(n_keys=200, l0_tables=2, ckpt_chunks=2)
    specs = get_clients(1, ops=3) + multiget_clients(1, ops=2, batch=4)
    report = run_serving("shared", specs, store=store)
    assert report["errors"] == 0
    plans = report["plans"]
    per = plans["per_graph"]
    for name in ("lsm_get", "lsm_multiget"):
        assert per[name]["probes"] >= 1, plans
        assert per[name]["graph_version"] >= 1
    assert plans["global"]["compiles"] >= 1
