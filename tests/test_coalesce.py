"""Extent-coalescing tests: the fuse pass, the carrier/satellite
lifecycle, scatter views, and the decomposition fallbacks.

Covers the invariants docs/ARCHITECTURE.md ("Direct I/O & extent
coalescing") promises:

* only statically-adjacent same-fd single-request PREAD runs fuse; gaps,
  overlaps, fd changes, link chains and non-static args break a run;
* a full super-read scatters zero-copy views and every member terminates
  exactly once; the shared slab recycles only after every view releases;
* a short read (EOF inside the fused range) or a device error decomposes
  to per-extent reads that are byte-identical to sync execution — EIO
  lands on exactly the extent that owns it, and the session ledger
  invariant still holds;
* a demanded satellite whose carrier died is decomposed on the spot.
"""

import errno

import pytest

from repro.core import Foreactor, MemDevice, Sys, io
from repro.core.buffers import BufferPool
from repro.core.coalesce import (ExtentCoalescer, MAX_FUSED_BYTES,
                                 _pool_alignment)
from repro.core.patterns import register_patterns
from repro.core.syscalls import IORequest, ReqState

from test_conformance import assert_ledger_invariant


def _req(fd=7, size=8, off=0, **kw):
    return IORequest(sc=Sys.PREAD, args=(fd, size, off), **kw)


def _chains(reqs):
    return [[r] for r in reqs]


# -- fuse pass ----------------------------------------------------------------

def test_fuse_adjacent_run_collapses_to_carrier():
    c = ExtentCoalescer(pool=None)
    reqs = [_req(off=i * 8) for i in range(6)]
    out = c.fuse(_chains(reqs))
    assert out == [[reqs[0]]]
    assert all(r.fused is reqs[0].fused for r in reqs)
    assert reqs[0].runner is not None  # carrier carries the super-read
    assert all(r.runner is None for r in reqs[1:])
    s = c.stats.snapshot()
    assert s["super_reads"] == 1 and s["extents_fused"] == 6
    assert s["bytes_fused"] == 48


def test_fuse_breaks_on_gap_overlap_and_fd_change():
    c = ExtentCoalescer(pool=None)
    gap = [_req(off=0), _req(off=8), _req(off=24)]  # 8..16 missing
    out = c.fuse(_chains(gap))
    assert [r.args for chain in out for r in chain] == \
        [gap[0].args, gap[2].args]  # first two fused, third standalone
    assert gap[2].fused is None

    c = ExtentCoalescer(pool=None)
    overlap = [_req(off=0), _req(off=8), _req(off=12)]  # re-reads 12..16
    out = c.fuse(_chains(overlap))
    assert overlap[2].fused is None

    c = ExtentCoalescer(pool=None)
    fds = [_req(fd=7, off=0), _req(fd=7, off=8),
           _req(fd=9, off=16), _req(fd=9, off=24)]
    out = c.fuse(_chains(fds))
    # two separate runs, one per fd — never fused across the fd change
    assert len(out) == 2
    assert fds[0].fused is not fds[2].fused
    assert c.stats.snapshot()["super_reads"] == 2


def test_fuse_epoch_stride_makes_one_run_per_epoch():
    """The miner's loop shapes re-start each epoch at a strided base
    offset; the fuse pass must emit one super-read per epoch, never fusing
    across the stride discontinuity."""
    c = ExtentCoalescer(pool=None)
    epoch0 = [_req(off=i * 8) for i in range(4)]          # 0..32
    epoch1 = [_req(off=4096 + i * 8) for i in range(4)]   # 4096..4128
    out = c.fuse(_chains(epoch0 + epoch1))
    assert len(out) == 2
    assert epoch0[0].fused is not epoch1[0].fused
    assert epoch0[3].fused is epoch0[0].fused
    assert epoch1[0].fused.offset == 4096


def test_fuse_refuses_short_runs_links_and_non_static_args():
    c = ExtentCoalescer(pool=None)
    single = _req()
    out = c.fuse(_chains([single]))
    assert out == [[single]] and single.fused is None  # < MIN_RUN

    linked = [_req(off=0, link=True), _req(off=8)]
    out = c.fuse(_chains(linked))
    assert all(r.fused is None for r in linked)

    chain = [_req(off=0), _req(off=8)]
    out = c.fuse([chain])  # one 2-request link chain, not two singletons
    assert out == [chain] and chain[0].fused is None

    from repro.core.syscalls import FromRequest
    dyn = [_req(off=0),
           IORequest(sc=Sys.PREAD, args=(7, 8, FromRequest(_req())))]
    c.fuse(_chains(dyn))
    assert all(r.fused is None for r in dyn)


def test_fuse_splits_at_max_bytes():
    c = ExtentCoalescer(pool=None, max_bytes=32)
    reqs = [_req(off=i * 8) for i in range(6)]  # 48 bytes total
    out = c.fuse(_chains(reqs))
    assert len(out) == 2  # 32-byte super-read + 16-byte super-read
    assert reqs[0].fused.total == 32 and reqs[4].fused.total == 16
    assert MAX_FUSED_BYTES == 1 << 22  # pinned: the pool's top size class


def test_pool_alignment_classes():
    class D:
        alignment = 0
    d = D()
    assert _pool_alignment(d) == 0
    d.alignment = 512
    assert _pool_alignment(d) == 512
    d.alignment = 4096
    assert _pool_alignment(d) == 4096
    d.alignment = 520  # odd block size still needs the larger class
    assert _pool_alignment(d) == 4096


# -- carrier execution: scatter and decomposition -----------------------------

def _mem(payload=bytes(range(256)), path="/f"):
    dev = MemDevice()
    fd = dev.open(path, "w")
    dev.pwrite(fd, payload, 0)
    dev.close(fd)
    return dev, dev.open(path, "r")


def test_scatter_views_are_zero_copy_and_slab_recycles_once_released():
    dev, fd = _mem()
    pool = BufferPool()
    c = ExtentCoalescer(pool)
    reqs = [_req(fd=fd, size=16, off=i * 16) for i in range(4)]
    c.fuse(_chains(reqs))
    reqs[0].claim()  # a worker claims the carrier; satellites stay PREPARED
    result = reqs[0].runner(dev)
    reqs[0].finish(result)

    for i, r in enumerate(reqs):
        assert r.take_result() == bytes(range(i * 16, (i + 1) * 16))
    # every member materialized its bytes and dropped its ref: the parent
    # slab must be back on the freelist, in its aligned class
    snap = pool.snapshot()
    assert snap["leased_now"] == 0
    assert snap["aligned_leases"] == 0  # MemDevice: buffered class
    assert c.stats.snapshot()["scatters"] == 1


def test_short_read_at_eof_decomposes_per_extent():
    dev, fd = _mem(payload=bytes(range(40)))  # EOF at 40
    c = ExtentCoalescer(BufferPool())
    reqs = [_req(fd=fd, size=16, off=i * 16) for i in range(4)]  # to 64
    c.fuse(_chains(reqs))
    reqs[0].claim()
    reqs[0].finish(reqs[0].runner(dev))
    assert reqs[0].take_result() == bytes(range(16))
    assert reqs[1].take_result() == bytes(range(16, 32))
    assert reqs[2].take_result() == bytes(range(32, 40))  # short, as sync
    assert reqs[3].take_result() == b""  # past EOF, as sync
    s = c.stats.snapshot()
    assert s["decompositions"] == 1 and s["scatters"] == 0


class SectorFaultDevice(MemDevice):
    """EIO on any read that *touches* the bad byte range — a bad block:
    the fused read spanning it fails, and so does exactly one extent."""

    def __init__(self, bad_lo, bad_hi):
        super().__init__()
        self.bad = (bad_lo, bad_hi)

    def _check(self, offset, size):
        lo, hi = self.bad
        if offset < hi and offset + size > lo:
            raise OSError(errno.EIO, f"bad sector {lo}..{hi}")

    def pread(self, fd, size, offset):
        self._check(offset, size)
        return super().pread(fd, size, offset)

    def pread_into(self, fd, buf, offset):
        self._check(offset, len(buf))
        return super().pread_into(fd, buf, offset)


def test_eio_mid_fused_read_lands_on_exactly_the_owning_extent():
    dev = SectorFaultDevice(36, 40)
    fd = dev.open("/f", "w")
    dev.pwrite(fd, bytes(range(64)), 0)
    c = ExtentCoalescer(BufferPool())
    reqs = [_req(fd=fd, size=16, off=i * 16) for i in range(4)]
    c.fuse(_chains(reqs))
    reqs[0].claim()
    reqs[0].finish(reqs[0].runner(dev))  # fused read spans 36..40 -> EIO
    assert reqs[0].take_result() == bytes(range(16))
    assert reqs[1].take_result() == bytes(range(16, 32))
    with pytest.raises(OSError) as exc:
        reqs[2].wait_result()  # extent 32..48 owns the bad sector
    assert exc.value.errno == errno.EIO
    assert reqs[3].take_result() == bytes(range(48, 64))
    # each member reached COMPLETED exactly once (no double-finish)
    assert all(r.state is ReqState.COMPLETED for r in reqs)
    assert c.stats.snapshot()["decompositions"] == 1


def test_carrier_eio_cancels_nothing_twice_and_satellites_still_serve():
    """Bad sector inside the *carrier's* extent: the fused read fails, the
    decomposed carrier re-read fails too (its own error), but every
    satellite still gets its own bytes."""
    dev = SectorFaultDevice(4, 8)
    fd = dev.open("/f", "w")
    dev.pwrite(fd, bytes(range(48)), 0)
    c = ExtentCoalescer(BufferPool())
    reqs = [_req(fd=fd, size=16, off=i * 16) for i in range(3)]
    c.fuse(_chains(reqs))
    reqs[0].claim()
    with pytest.raises(OSError):
        reqs[0].runner(dev)  # worker would finish the carrier with this
    reqs[0].finish(error=OSError(errno.EIO, "EIO"))
    assert reqs[1].take_result() == bytes(range(16, 32))
    assert reqs[2].take_result() == bytes(range(32, 48))


def test_cancelled_satellite_is_skipped_by_scatter():
    dev, fd = _mem()
    c = ExtentCoalescer(BufferPool())
    reqs = [_req(fd=fd, size=16, off=i * 16) for i in range(3)]
    c.fuse(_chains(reqs))
    reqs[0].claim()
    assert reqs[1].cancel()  # early exit cancelled it before execution
    reqs[0].finish(reqs[0].runner(dev))
    assert reqs[1].state is ReqState.CANCELLED  # scatter must not revive it
    assert reqs[0].take_result() == bytes(range(16))
    assert reqs[2].take_result() == bytes(range(32, 48))


def test_demanded_satellite_decomposes_after_carrier_cancel():
    dev, fd = _mem()
    c = ExtentCoalescer(BufferPool())
    reqs = [_req(fd=fd, size=16, off=i * 16) for i in range(3)]
    c.fuse(_chains(reqs))
    assert reqs[0].cancel()  # carrier evicted before any worker ran it
    # the satellite was never dispatched, so the demand path's on_demand
    # hook claims it itself and serves the extent inline
    reqs[1].fused.on_demand(dev, reqs[1])
    assert reqs[1].take_result() == bytes(range(16, 32))
    assert c.stats.snapshot()["demand_decompositions"] == 1
    # an already-cancelled satellite is left alone
    assert reqs[2].cancel()
    reqs[2].fused.on_demand(dev, reqs[2])
    assert reqs[2].state is ReqState.CANCELLED


def test_unleased_fallback_scatters_bytes():
    dev, fd = _mem()
    c = ExtentCoalescer(pool=None)  # no pool: plain-bytes super-read
    reqs = [_req(fd=fd, size=16, off=i * 16) for i in range(3)]
    c.fuse(_chains(reqs))
    reqs[0].claim()
    reqs[0].finish(reqs[0].runner(dev))
    assert [r.take_result() for r in reqs] == \
        [bytes(range(i * 16, (i + 1) * 16)) for i in range(3)]
    assert c.stats.snapshot()["unleased_fallbacks"] == 1


# -- LeaseView refcounts ------------------------------------------------------

def test_lease_view_refcounts_pin_parent_slab():
    pool = BufferPool()
    lease = pool.lease(64, alignment=512)
    v1 = lease.view(0, 16)
    v2 = lease.view(16, 16)
    lease.release()  # parent's own ref gone; views still pin the slab
    assert pool.snapshot()["leased_now"] == 1
    assert v1.to_bytes() == bytes(16)
    v1.release()
    v1.release()  # idempotent: must not double-release the parent
    assert pool.snapshot()["leased_now"] == 1
    v2.addref()
    v2.release()
    assert pool.snapshot()["leased_now"] == 1
    v2.release()  # last ref: slab recycles into the (cls, aligned) bucket
    assert pool.snapshot()["leased_now"] == 0
    again = pool.lease(64, alignment=512)
    assert pool.snapshot()["recycle_hits"] >= 1
    again.release()


def test_lease_view_bounds_checked():
    pool = BufferPool()
    lease = pool.lease(64)
    slab = len(lease.mv)  # bounds are slab-relative (the size class)
    with pytest.raises(ValueError):
        lease.view(slab - 8, 16)
    with pytest.raises(ValueError):
        lease.view(-1, 4)
    lease.release()


# -- end-to-end through the engine -------------------------------------------

def _run_extent_program(dev, extents, coalesce, backend="io_uring",
                        depth=64):
    fa = Foreactor(device=dev, backend=backend, depth=depth, workers=4,
                   coalesce=coalesce)
    register_patterns(fa)

    @fa.wrap("pread_extents", lambda extents: {"extents": extents})
    def prog(extents):
        out = []
        for fd, size, off in extents:
            try:
                out.append(io.pread(dev, fd, size, off))
            except OSError as e:
                out.append(("EIO", e.errno))
        return out

    try:
        return prog(extents), fa.total_stats
    finally:
        fa.shutdown()


def _extent_dev(payload):
    dev = MemDevice()
    fd = dev.open("/e", "w")
    dev.pwrite(fd, payload, 0)
    dev.close(fd)
    return dev


@pytest.mark.parametrize("case", ["adjacent", "eof_short", "strided"])
def test_engine_coalesced_matches_sync_oracle(case):
    payload = bytes((i * 11) % 251 for i in range(512))
    if case == "adjacent":
        mk = lambda fd: [(fd, 32, i * 32) for i in range(16)]
    elif case == "eof_short":
        # run extends past EOF: fused read comes up short, decomposes
        mk = lambda fd: [(fd, 64, i * 64) for i in range(10)]  # to 640
    else:
        mk = lambda fd: [(fd, 16, e * 256 + i * 16)
                         for e in range(2) for i in range(8)]

    dev = _extent_dev(payload)
    fd = dev.open("/e", "r")
    ref, ref_stats = _run_extent_program(dev, mk(fd), False, backend="sync",
                                         depth=0)
    dev.close(fd)

    dev = _extent_dev(payload)
    dev.alignment = 512  # direct lane: leases must come aligned
    fd = dev.open("/e", "r")
    got, stats = _run_extent_program(dev, mk(fd), True)
    dev.close(fd)
    assert got == ref
    assert_ledger_invariant(stats)
    assert_ledger_invariant(ref_stats)


def test_engine_coalesced_eio_matches_sync_oracle():
    payload = bytes(range(256))

    def build():
        dev = SectorFaultDevice(100, 104)
        fd = dev.open("/e", "w")
        dev.pwrite(fd, payload, 0)
        dev.close(fd)
        return dev, dev.open("/e", "r")

    dev, fd = build()
    extents = [(fd, 32, i * 32) for i in range(8)]
    ref, _ = _run_extent_program(dev, extents, False, backend="sync",
                                 depth=0)
    dev, fd = build()
    dev.alignment = 512
    extents = [(fd, 32, i * 32) for i in range(8)]
    got, stats = _run_extent_program(dev, extents, True)
    assert got == ref
    assert got[3] == ("EIO", errno.EIO)  # extent 96..128 owns the bad block
    assert_ledger_invariant(stats)
