"""End-to-end training runtime tests: loss goes down, checkpoint restart
is bit-deterministic with the continuous run, straggler accounting."""

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import MemDevice
from repro.data import DataConfig, ShardedTokenDataset, TokenBatchLoader, write_synthetic_dataset
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def setup(steps=12, ckpt_every=0, root="/ck", dev=None, schedule_steps=None):
    dev = dev or MemDevice()
    cfg = get_config("tinyllama_1_1b", smoke=True)
    dcfg = DataConfig(seq_len=32, batch_size=4, seed=5)
    write_synthetic_dataset(dev, "/data", dcfg, 2, 24, vocab_size=cfg.vocab_size)
    ds = ShardedTokenDataset(dev, [f"/data/shard_{i:05d}.rio" for i in range(2)])
    loader = TokenBatchLoader(ds, dcfg, prefetch=False)
    model = build_model(cfg)
    # schedule_steps pins the LR schedule independently of how far this
    # (possibly interrupted) run goes — matching production restarts.
    opt = AdamWConfig(lr=1e-3, warmup_steps=2,
                      total_steps=schedule_steps or steps, grad_clip=1.0)
    ckpt = CheckpointManager(dev, root, num_shards=2, chunk_bytes=1 << 14) \
        if ckpt_every else None
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every, log_every=0)
    return dev, Trainer(model, opt, loader, ckpt, make_host_mesh(), tcfg)


def test_loss_decreases():
    _, tr = setup(steps=15)
    out = tr.fit()
    losses = out["losses"]
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    assert out["final_step"] == 15


def test_checkpoint_restart_is_deterministic():
    # continuous 12-step run
    dev1, tr1 = setup(steps=12, ckpt_every=50, root="/ck1")
    out1 = tr1.fit()
    # interrupted run: 6 steps, checkpoint, then resume to 12
    dev2, tr2 = setup(steps=6, ckpt_every=6, root="/ck2", schedule_steps=12)
    tr2.fit()
    dev2b, tr2b = setup(steps=12, ckpt_every=50, root="/ck2", dev=dev2)
    out2 = tr2b.fit()
    # identical final params
    p1 = jax.tree.leaves(out1["state"]["params"])
    p2 = jax.tree.leaves(out2["state"]["params"])
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_emergency_checkpoint_on_crash():
    dev, tr = setup(steps=50, ckpt_every=100, root="/ck")
    calls = {"n": 0}
    orig_load = tr.loader.load

    def exploding_load(e, s):
        calls["n"] += 1
        if calls["n"] > 5:
            raise RuntimeError("node failure!")
        return orig_load(e, s)

    tr.loader.load = exploding_load
    with pytest.raises(RuntimeError, match="node failure"):
        tr.fit()
    assert tr.ckpt.latest_step() is not None  # emergency save landed
    # and it restores
    out = tr.ckpt.restore_latest()
    assert out is not None
