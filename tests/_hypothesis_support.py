"""Make ``hypothesis`` optional for the tier-1 suite.

Property-based tests are the deep end of the suite; the non-property tests
must collect and run even in an environment without ``hypothesis`` installed
(it is listed in ``requirements-dev.txt``).  Importing ``given``/``settings``/
``st`` from here instead of from ``hypothesis`` keeps the test modules
unchanged: with hypothesis present the real objects are re-exported, without
it the decorators degrade to per-test skips (module-level
``pytest.importorskip`` would skip the whole file, which is exactly what we
do not want).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # degrade property tests to visible skips
    HAS_HYPOTHESIS = False

    class _StrategyStub:
        """Accepts any strategy construction (st.integers(...) etc.)."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _StrategyStub()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            # keep the collected name; deliberately no functools.wraps — the
            # original signature's params are hypothesis-provided, and pytest
            # would demand fixtures for them
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
