"""Write-path speculation: staged checkpoint saves, speculative shard
writes, and write-behind checkpointing vs the serial write path.

Until the undoable-write extension (docs/ARCHITECTURE.md, "Undoable write
speculation"), the save path was the one storage-heavy consumer speculation
could not touch: ``is_pure`` gated pwrite/creating-open behind weak edges,
so every checkpoint op paid full device latency in sequence.  This section
measures what lifting that restriction buys:

* **save** — ``CheckpointManager.save`` (one staged write graph: creates,
  extent writes, fsync/close barriers, marker last) across shard count ×
  speculation depth, against the serial sync-backend baseline.  Headline:
  ``save.speedup_4shards`` (best speculated vs serial at 4 shards), the
  acceptance gate is >= 1.5x.
* **record_shard** — ``repro.store.recordio.write_shard`` with a Foreactor
  (one ``write_file`` graph) vs the serial append loop.
* **write_behind** — a synthetic training loop (fixed compute per step,
  checkpoint every k steps): serial inline saves vs ``save_async`` over the
  speculated graph.  Measures wall time and the training-thread stall
  (``Trainer``'s ``ckpt_wait_s`` equivalent).

Results land in ``benchmarks/results/write.json`` (common.write_results
conventions; table rendered into docs/BENCHMARKS.md by
``tools/bench_report.py``).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import DeviceProfile, Foreactor, MemDevice, SimulatedDevice
from repro.store.recordio import write_shard

from .common import Row, timeit_min, write_results

SHARD_COUNTS = (1, 2, 4, 8)
#: (label, backend, depth) — serial is the pre-staging write path
MODES = (
    ("serial", "sync", 0),
    ("spec_d8", "io_uring", 8),
    ("spec_d64", "io_uring", 64),
    ("adaptive", "io_uring", "adaptive"),
)

#: ms-scale per-op latency so Python sleep granularity cannot blur the
#: effect; 16 channels so a speculated batch actually overlaps
WRITE_PROFILE = DeviceProfile(channels=16, base_latency=1.2e-3,
                              metadata_latency=1.0e-3, per_byte=1.0e-9,
                              crossing_cost=4e-6)

CHUNK = 64 * 1024
NUM_EXTENTS = 48  # 3 MiB tree -> 48 extent writes round-robined over shards


def _tree() -> Dict[str, np.ndarray]:
    return {"w": np.arange(CHUNK * NUM_EXTENTS // 4, dtype=np.float32)}


def bench_save(repeats: int = 2) -> Dict[str, Dict]:
    tree = _tree()
    out: Dict[str, Dict] = {"config": {
        "shard_counts": list(SHARD_COUNTS), "chunk_bytes": CHUNK,
        "num_extents": NUM_EXTENTS,
        "modes": [m[0] for m in MODES],
    }}
    for shards in SHARD_COUNTS:
        for label, backend, depth in MODES:
            dev = SimulatedDevice(MemDevice(), WRITE_PROFILE)
            fa = Foreactor(device=dev, backend=backend, depth=depth,
                           workers=16)
            mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=shards,
                                    chunk_bytes=CHUNK, keep=2)
            step = [0]

            def one_save():
                step[0] += 1
                mgr.save(step[0], tree)

            t = timeit_min(one_save, repeats=repeats, warmup=1)
            # committed state must be complete and restorable every time
            restored, _ = mgr.restore(step[0], check_crc=True)
            assert np.array_equal(restored["['w']"], tree["w"]), label
            fa.shutdown()
            out.setdefault(label, {})[str(shards)] = {
                "seconds": t,
                "mb_per_s": CHUNK * NUM_EXTENTS / t / 1e6,
            }
    best4 = min(out[m[0]]["4"]["seconds"] for m in MODES[1:])
    out["speedup_4shards"] = out["serial"]["4"]["seconds"] / best4
    out["speedup_8shards"] = (out["serial"]["8"]["seconds"]
                              / min(out[m[0]]["8"]["seconds"]
                                    for m in MODES[1:]))
    return out


def bench_record_shard(num_records: int = 64, record_bytes: int = 4096,
                       repeats: int = 2) -> Dict[str, Dict]:
    records = [bytes([i % 251]) * record_bytes for i in range(num_records)]
    out: Dict[str, Dict] = {"config": {
        "num_records": num_records, "record_bytes": record_bytes,
    }}
    for label, backend, depth in (("serial", "sync", 0),
                                  ("spec", "io_uring", 128)):
        dev = SimulatedDevice(MemDevice(), WRITE_PROFILE)
        fa = Foreactor(device=dev, backend=backend, depth=depth, workers=16)
        n = [0]

        def one_shard():
            n[0] += 1
            write_shard(dev, f"/data/s{n[0]}.rio", records,
                        fa=None if label == "serial" else fa)

        t = timeit_min(one_shard, repeats=repeats, warmup=1)
        fa.shutdown()
        out[label] = {"seconds": t,
                      "mb_per_s": num_records * record_bytes / t / 1e6}
    out["speedup"] = out["serial"]["seconds"] / out["spec"]["seconds"]
    return out


def bench_write_behind(steps: int = 8, ckpt_every: int = 2,
                       compute_s: float = 0.02) -> Dict[str, Dict]:
    """The trainer's view: how much wall time does overlapping the
    speculated save graph with step compute recover?"""
    tree = _tree()
    out: Dict[str, Dict] = {"config": {
        "steps": steps, "ckpt_every": ckpt_every, "compute_s": compute_s,
    }}
    for label, write_behind in (("serial", False), ("write_behind", True)):
        dev = SimulatedDevice(MemDevice(), WRITE_PROFILE)
        fa = Foreactor(device=dev, backend="io_uring", depth=64, workers=16)
        mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=4,
                                chunk_bytes=CHUNK, keep=3)
        mgr.save(0, tree)  # warm the queue pairs + graph
        stall = 0.0
        t0 = time.perf_counter()
        for s in range(1, steps + 1):
            time.sleep(compute_s)  # the jitted train step
            if s % ckpt_every == 0:
                c0 = time.perf_counter()
                if write_behind:
                    mgr.save_async(s, tree)
                else:
                    mgr.save(s, tree)
                stall += time.perf_counter() - c0
        mgr.wait_pending()
        wall = time.perf_counter() - t0
        assert mgr.restore_latest() is not None
        fa.shutdown()
        out[label] = {"wall_seconds": wall, "stall_seconds": stall}
    out["speedup"] = (out["serial"]["wall_seconds"]
                      / out["write_behind"]["wall_seconds"])
    out["stall_ratio"] = (out["write_behind"]["stall_seconds"]
                          / max(out["serial"]["stall_seconds"], 1e-9))
    return out


def run() -> List[Row]:
    save = bench_save()
    shard = bench_record_shard()
    wb = bench_write_behind()
    path = write_results("write", {"save": save, "record_shard": shard,
                                   "write_behind": wb})
    rows: List[Row] = []
    for label, _b, _d in MODES:
        for n in SHARD_COUNTS:
            cell = save[label][str(n)]
            rows.append((f"write_save_{label}_shards{n}",
                         cell["seconds"] * 1e6,
                         f"bw={cell['mb_per_s']:.1f}MB/s"))
    rows.append(("write_save_speedup_4shards", 0.0,
                 f"x{save['speedup_4shards']:.2f}"))
    for label in ("serial", "spec"):
        rows.append((f"write_record_shard_{label}",
                     shard[label]["seconds"] * 1e6,
                     f"bw={shard[label]['mb_per_s']:.1f}MB/s"))
    for label in ("serial", "write_behind"):
        rows.append((f"write_behind_{label}",
                     wb[label]["wall_seconds"] * 1e6,
                     f"stall={wb[label]['stall_seconds'] * 1e3:.0f}ms"))
    rows.append(("write_results_json", 0.0, path))
    return rows


if __name__ == "__main__":
    for line in run():
        print(line)
