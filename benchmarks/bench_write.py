"""Write-path speculation: staged checkpoint saves, speculative shard
writes, and write-behind checkpointing vs the serial write path.

Until the undoable-write extension (docs/ARCHITECTURE.md, "Undoable write
speculation"), the save path was the one storage-heavy consumer speculation
could not touch: ``is_pure`` gated pwrite/creating-open behind weak edges,
so every checkpoint op paid full device latency in sequence.  This section
measures what lifting that restriction buys:

* **save** — ``CheckpointManager.save`` (one staged write graph: creates,
  extent writes, fsync/close barriers, marker last) across shard count ×
  speculation depth, against the serial sync-backend baseline.  Headline:
  ``save.speedup_4shards`` (best speculated vs serial at 4 shards), the
  acceptance gate is >= 1.5x.
* **record_shard** — ``repro.store.recordio.write_shard`` with a Foreactor
  (one ``write_file`` graph) vs the serial append loop.
* **write_behind** — a synthetic training loop (fixed compute per step,
  checkpoint every k steps): serial inline saves vs ``save_async`` over the
  speculated graph.  Measures wall time and the training-thread stall
  (``Trainer``'s ``ckpt_wait_s`` equivalent).
* **delta** — ``save(..., delta=True)`` bytes written vs a full save at
  1% / 10% / 50% extent churn (device ``write_bytes`` counters; chained
  restore asserted byte-identical).  Acceptance gate: at 10% churn a delta
  save writes <= 0.2x the bytes of a full save.

Results land in ``benchmarks/results/write.json`` (common.write_results
conventions; table rendered into docs/BENCHMARKS.md by
``tools/bench_report.py``).  ``python -m benchmarks.bench_write
--dry-run --check`` is the CI smoke gate: a reduced sweep proves the write
path end to end, and the committed full-scale results must still satisfy
the acceptance invariants.
"""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import DeviceProfile, Foreactor, MemDevice, SimulatedDevice
from repro.store.recordio import write_shard

from .common import Row, timeit_min, write_results

SHARD_COUNTS = (1, 2, 4, 8)
#: (label, backend, depth) — serial is the pre-staging write path
MODES = (
    ("serial", "sync", 0),
    ("spec_d8", "io_uring", 8),
    ("spec_d64", "io_uring", 64),
    ("adaptive", "io_uring", "adaptive"),
)

#: ms-scale per-op latency so Python sleep granularity cannot blur the
#: effect; 16 channels so a speculated batch actually overlaps
WRITE_PROFILE = DeviceProfile(channels=16, base_latency=1.2e-3,
                              metadata_latency=1.0e-3, per_byte=1.0e-9,
                              crossing_cost=4e-6)

CHUNK = 64 * 1024
NUM_EXTENTS = 48  # 3 MiB tree -> 48 extent writes round-robined over shards


def _tree() -> Dict[str, np.ndarray]:
    return {"w": np.arange(CHUNK * NUM_EXTENTS // 4, dtype=np.float32)}


def bench_save(repeats: int = 2,
               shard_counts: Sequence[int] = SHARD_COUNTS,
               modes: Sequence[Tuple] = MODES) -> Dict[str, Dict]:
    tree = _tree()
    out: Dict[str, Dict] = {"config": {
        "shard_counts": list(shard_counts), "chunk_bytes": CHUNK,
        "num_extents": NUM_EXTENTS,
        "modes": [m[0] for m in modes],
    }}
    for shards in shard_counts:
        for label, backend, depth in modes:
            dev = SimulatedDevice(MemDevice(), WRITE_PROFILE)
            fa = Foreactor(device=dev, backend=backend, depth=depth,
                           workers=16)
            mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=shards,
                                    chunk_bytes=CHUNK, keep=2)
            step = [0]

            def one_save():
                step[0] += 1
                mgr.save(step[0], tree)

            t = timeit_min(one_save, repeats=repeats, warmup=1)
            # committed state must be complete and restorable every time
            restored, _ = mgr.restore(step[0], check_crc=True)
            assert np.array_equal(restored["['w']"], tree["w"]), label
            fa.shutdown()
            out.setdefault(label, {})[str(shards)] = {
                "seconds": t,
                "mb_per_s": CHUNK * NUM_EXTENTS / t / 1e6,
            }
    for n in shard_counts:
        if n in (4, 8):
            best = min(out[m[0]][str(n)]["seconds"] for m in modes[1:])
            out[f"speedup_{n}shards"] = (out["serial"][str(n)]["seconds"]
                                         / best)
    return out


def bench_record_shard(num_records: int = 64, record_bytes: int = 4096,
                       repeats: int = 2) -> Dict[str, Dict]:
    records = [bytes([i % 251]) * record_bytes for i in range(num_records)]
    out: Dict[str, Dict] = {"config": {
        "num_records": num_records, "record_bytes": record_bytes,
    }}
    for label, backend, depth in (("serial", "sync", 0),
                                  ("spec", "io_uring", 128)):
        dev = SimulatedDevice(MemDevice(), WRITE_PROFILE)
        fa = Foreactor(device=dev, backend=backend, depth=depth, workers=16)
        n = [0]

        def one_shard():
            n[0] += 1
            write_shard(dev, f"/data/s{n[0]}.rio", records,
                        fa=None if label == "serial" else fa)

        t = timeit_min(one_shard, repeats=repeats, warmup=1)
        fa.shutdown()
        out[label] = {"seconds": t,
                      "mb_per_s": num_records * record_bytes / t / 1e6}
    out["speedup"] = out["serial"]["seconds"] / out["spec"]["seconds"]
    return out


def bench_write_behind(steps: int = 8, ckpt_every: int = 2,
                       compute_s: float = 0.02) -> Dict[str, Dict]:
    """The trainer's view: how much wall time does overlapping the
    speculated save graph with step compute recover?"""
    tree = _tree()
    out: Dict[str, Dict] = {"config": {
        "steps": steps, "ckpt_every": ckpt_every, "compute_s": compute_s,
    }}
    for label, write_behind in (("serial", False), ("write_behind", True)):
        dev = SimulatedDevice(MemDevice(), WRITE_PROFILE)
        fa = Foreactor(device=dev, backend="io_uring", depth=64, workers=16)
        mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=4,
                                chunk_bytes=CHUNK, keep=3)
        mgr.save(0, tree)  # warm the queue pairs + graph
        stall = 0.0
        t0 = time.perf_counter()
        for s in range(1, steps + 1):
            time.sleep(compute_s)  # the jitted train step
            if s % ckpt_every == 0:
                c0 = time.perf_counter()
                if write_behind:
                    mgr.save_async(s, tree)
                else:
                    mgr.save(s, tree)
                stall += time.perf_counter() - c0
        mgr.wait_pending()
        wall = time.perf_counter() - t0
        assert mgr.restore_latest() is not None
        fa.shutdown()
        out[label] = {"wall_seconds": wall, "stall_seconds": stall}
    out["speedup"] = (out["serial"]["wall_seconds"]
                      / out["write_behind"]["wall_seconds"])
    out["stall_ratio"] = (out["write_behind"]["stall_seconds"]
                          / max(out["serial"]["stall_seconds"], 1e-9))
    return out


#: churn fractions for the delta section: what fraction of the tree's
#: extents mutate between consecutive saves
CHURNS = (0.01, 0.10, 0.50)


def bench_delta(churns: Sequence[float] = CHURNS,
                chain_len: int = 3) -> Dict[str, Dict]:
    """Bytes written by ``save(..., delta=True)`` vs the full baseline,
    counted on the device's ``write_bytes`` stats (a MemDevice without
    simulated latency — this section measures bytes, not seconds).  Churn
    is extent-granular: mutating one value inside an extent dirties its
    CRC, so ``frac`` of the extents change between saves — the localized
    "a few layers moved" update pattern delta checkpoints exist for."""
    ext_elems = CHUNK // 4  # float32 elements per extent
    out: Dict[str, Dict] = {"config": {
        "churns": list(churns), "chunk_bytes": CHUNK,
        "num_extents": NUM_EXTENTS, "chain_len": chain_len,
    }}
    for frac in churns:
        dev = MemDevice()
        fa = Foreactor(device=dev, backend="io_uring", depth=32, workers=8)
        mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=4,
                                chunk_bytes=CHUNK, keep=chain_len + 1)
        tree = _tree()
        b0 = dev.stats.snapshot()["write_bytes"]
        mgr.save(0, tree)
        full_bytes = dev.stats.snapshot()["write_bytes"] - b0
        n_churn = max(1, round(frac * NUM_EXTENTS))
        rng = np.random.default_rng(7)
        delta_bytes: List[int] = []
        for step in range(1, chain_len + 1):
            for e in rng.choice(NUM_EXTENTS, size=n_churn, replace=False):
                tree["w"][int(e) * ext_elems] = rng.random()
            b0 = dev.stats.snapshot()["write_bytes"]
            mgr.save(step, tree, delta=True)
            delta_bytes.append(dev.stats.snapshot()["write_bytes"] - b0)
        # a chained restore must reproduce the mutated tree byte-for-byte
        restored, _ = mgr.restore(chain_len, check_crc=True)
        assert np.array_equal(restored["['w']"], tree["w"]), frac
        fa.shutdown()
        mean_delta = float(np.mean(delta_bytes))
        out[f"churn_{frac:g}"] = {
            "changed_extents_per_save": n_churn,
            "full_bytes": int(full_bytes),
            "delta_bytes": [int(b) for b in delta_bytes],
            "mean_delta_bytes": mean_delta,
            "bytes_ratio": mean_delta / full_bytes,
        }
    return out


def collect(dry_run: bool = False) -> Dict[str, Dict]:
    if dry_run:
        save = bench_save(repeats=1, shard_counts=(1, 4),
                          modes=(MODES[0], MODES[1]))
        shard = bench_record_shard(num_records=16, repeats=1)
        wb = bench_write_behind(steps=4)
    else:
        save = bench_save()
        shard = bench_record_shard()
        wb = bench_write_behind()
    # the delta section counts bytes on an unthrottled MemDevice, so it is
    # cheap enough to run at full size even in the CI smoke gate
    delta = bench_delta()
    return {"save": save, "record_shard": shard, "write_behind": wb,
            "delta": delta}


def check(fresh: Dict, committed: Optional[Dict]) -> List[str]:
    """CI smoke gate.  The fresh (dry-run-sized) sweep proves the staged
    write path works end to end (every save restorable — asserted inline —
    and every timing positive); the committed full-scale results must still
    satisfy the acceptance invariants: >= 1.5x speculated save speedup at
    4 shards and a delta save writing <= 0.2x the full-save bytes at 10%
    churn."""
    errs: List[str] = []
    for label in fresh["save"]["config"]["modes"]:
        for n, cell in fresh["save"][label].items():
            if cell["seconds"] <= 0:
                errs.append(f"save {label}/{n}: non-positive time")
    for frac in fresh["delta"]["config"]["churns"]:
        cell = fresh["delta"][f"churn_{frac:g}"]
        if cell["mean_delta_bytes"] >= cell["full_bytes"]:
            errs.append(f"delta at churn {frac:g} wrote as much as a full "
                        f"save ({cell['mean_delta_bytes']:.0f} vs "
                        f"{cell['full_bytes']})")
    if fresh["delta"]["churn_0.1"]["bytes_ratio"] > 0.2:
        errs.append("delta bytes at 10% churn exceeded 0.2x full "
                    f"(ratio {fresh['delta']['churn_0.1']['bytes_ratio']:.3f})")
    if committed is not None:
        if committed["save"].get("speedup_4shards", 0.0) < 1.5:
            errs.append("committed save speedup at 4 shards fell below "
                        f"1.5x ({committed['save'].get('speedup_4shards')})")
        ratio = committed.get("delta", {}).get("churn_0.1",
                                               {}).get("bytes_ratio")
        if ratio is None or ratio > 0.2:
            errs.append(f"committed delta bytes_ratio at 10% churn is not "
                        f"<= 0.2 ({ratio})")
    return errs


def run() -> List[Row]:
    d = collect()
    save, shard, wb, delta = (d["save"], d["record_shard"],
                              d["write_behind"], d["delta"])
    path = write_results("write", d)
    rows: List[Row] = []
    for label, _b, _d in MODES:
        for n in SHARD_COUNTS:
            cell = save[label][str(n)]
            rows.append((f"write_save_{label}_shards{n}",
                         cell["seconds"] * 1e6,
                         f"bw={cell['mb_per_s']:.1f}MB/s"))
    rows.append(("write_save_speedup_4shards", 0.0,
                 f"x{save['speedup_4shards']:.2f}"))
    for label in ("serial", "spec"):
        rows.append((f"write_record_shard_{label}",
                     shard[label]["seconds"] * 1e6,
                     f"bw={shard[label]['mb_per_s']:.1f}MB/s"))
    for label in ("serial", "write_behind"):
        rows.append((f"write_behind_{label}",
                     wb[label]["wall_seconds"] * 1e6,
                     f"stall={wb[label]['stall_seconds'] * 1e3:.0f}ms"))
    for frac in delta["config"]["churns"]:
        cell = delta[f"churn_{frac:g}"]
        rows.append((f"write_delta_churn{int(frac * 100)}pct", 0.0,
                     f"bytes_ratio={cell['bytes_ratio']:.3f}"))
    rows.append(("write_results_json", 0.0, path))
    return rows


def main(argv: List[str]) -> int:
    import os

    dry = "--dry-run" in argv
    fresh = collect(dry_run=dry)
    if "--check" in argv:
        results_path = os.path.join(os.path.dirname(__file__), "results",
                                    "write.json")
        committed = None
        if os.path.exists(results_path):
            with open(results_path) as f:
                committed = json.load(f)
        errs = check(fresh, committed)
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        print("write-smoke:", "FAIL" if errs else "ok")
        return 1 if errs else 0
    if not dry:
        write_results("write", fresh)
        print("wrote benchmarks/results/write.json")
    summary = {"save_speedup_4shards": fresh["save"].get("speedup_4shards"),
               "delta_ratios": {k: v["bytes_ratio"]
                                for k, v in fresh["delta"].items()
                                if k.startswith("churn_")}}
    print(json.dumps(summary, indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
