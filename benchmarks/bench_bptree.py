"""Paper Fig. 7 + Table 1: B+-tree Scan / bulk Load throughput vs degree,
and the io_uring vs user-threads backend comparison."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import Foreactor, MemDevice
from repro.store import plugins
from repro.store.bptree import BPTree

from .common import Row, sim, timeit


def _data(n: int):
    keys = np.arange(n, dtype=np.uint64) * 3
    vals = keys * 7 + 1
    return keys, vals


def bench_scan_load(degrees=(64, 256, 510), n: int = 60000) -> List[Row]:
    keys, vals = _data(n)
    rows: List[Row] = []
    for degree in degrees:
        inner = MemDevice()
        # --- Load ---
        for use_fa, label in ((False, "sync"), (True, "foreactor")):
            dev = sim(inner)
            tree = BPTree(dev, f"/bpt_{degree}_{label}.db", degree=degree)
            if use_fa:
                fa = Foreactor(device=dev, backend="io_uring", depth=64)
                plugins.register_all(fa)
                load = fa.wrap("bptree_load", plugins.capture_bptree_load)(
                    plugins.load_with_graph)
                t = timeit(lambda: load(tree, keys, vals))
                fa.shutdown()
            else:
                t = timeit(lambda: tree.bulk_load(keys, vals))
            rows.append((f"bpt_load_deg{degree}_{label}", t * 1e6,
                         f"m_recs_per_s={n / t / 1e6:.2f}"))
        # --- Scan (10 range scans over the foreactor-loaded tree) ---
        lo, hi = int(keys[n // 10]), int(keys[9 * n // 10])
        for use_fa, label in ((False, "sync"), (True, "foreactor")):
            dev = sim(inner)
            tree = BPTree(dev, f"/bpt_{degree}_foreactor.db").open()
            if use_fa:
                fa = Foreactor(device=dev, backend="io_uring", depth=64)
                plugins.register_all(fa)
                scan = fa.wrap("bptree_scan", plugins.capture_bptree_scan)(
                    plugins.scan_with_graph)
                t = timeit(lambda: scan(tree, lo, hi))
                fa.shutdown()
            else:
                t = timeit(lambda: tree.scan(lo, hi))
            nrec = 8 * n // 10
            rows.append((f"bpt_scan_deg{degree}_{label}", t * 1e6,
                         f"m_recs_per_s={nrec / t / 1e6:.2f}"))
    return rows


def bench_backends(n: int = 60000, degree: int = 510) -> List[Row]:
    """Table 1: same graphs, io_uring vs user-threads backend."""
    keys, vals = _data(n)
    inner = MemDevice()
    BPTree(sim(inner), "/warm.db", degree=degree).bulk_load(keys, vals)
    rows: List[Row] = []
    lo, hi = int(keys[0]), int(keys[-1])
    for backend in ("io_uring", "user_threads"):
        dev = sim(inner)
        fa = Foreactor(device=dev, backend=backend, depth=64)
        plugins.register_all(fa)
        tree = BPTree(dev, "/warm.db").open()
        scan = fa.wrap("bptree_scan", plugins.capture_bptree_scan)(
            plugins.scan_with_graph)
        t = timeit(lambda: scan(tree, lo, hi))
        rows.append((f"bpt_scan_backend_{backend}", t * 1e6,
                     f"m_recs_per_s={n / t / 1e6:.2f}"))
        tree2 = BPTree(dev, f"/load_{backend}.db", degree=degree)
        load = fa.wrap("bptree_load", plugins.capture_bptree_load)(
            plugins.load_with_graph)
        t = timeit(lambda: load(tree2, keys, vals))
        rows.append((f"bpt_load_backend_{backend}", t * 1e6,
                     f"m_recs_per_s={n / t / 1e6:.2f}"))
        fa.shutdown()
    return rows


def run() -> List[Row]:
    return bench_scan_load() + bench_backends()


if __name__ == "__main__":
    # standalone entry point, same CSV shape as benchmarks.run
    from .common import fmt

    print("name,us_per_call,derived")
    for line in fmt(run()):
        print(line, flush=True)
