"""Open-loop serving sweep: throughput vs p99 to saturation (and past it).

The closed-loop serving benchmark (``bench_serve``) lets an overloaded
server slow its own clients down, so offered load self-throttles at
capacity and queueing collapse is structurally invisible.  This benchmark
drives the same serving substrate (:mod:`repro.launch.ioserver`: LSM point
gets on ``SERVE_PROFILE``, one fresh tenant session per request) with a
fixed-rate **open-loop** arrival schedule instead: each sweep cell replays
a seeded Poisson trace of ``sessions x RATE_PER_SESSION`` arrivals/s for
``DURATION_S`` seconds, regardless of whether the server keeps up.
Latency is virtual-time (measured from the *scheduled* arrival — wrk2's
coordinated-omission correction), so once the arrival rate passes the
service capacity, the backlog lands in p99 instead of silently stretching
the run.

Reported per (mode, sessions) cell: offered vs achieved rate, p50/p99,
and the peak in-flight session count (arrived, not yet completed —
recovered post hoc from the event log; the top cells push it past 1k
concurrent sessions, the paper-scale regime the scheduler's O(1)
admission path and the pooled completion primitive exist for).  The
*saturation knee* per mode is the largest cell still sustained: achieved
rate within :data:`KNEE_ACHIEVED_FRAC` of offered AND p99 within
:data:`KNEE_P99_INFLATION` of the mode's unloaded p99.

``python -m benchmarks.bench_openloop`` writes
``benchmarks/results/openloop.json`` (rendered into docs/BENCHMARKS.md by
``tools/bench_report.py``); ``--table`` renders the docs/TUNING.md sweep
table; ``--dry-run --check`` is the CI smoke gate (tiny cells, structural
assertions against the run plus acceptance invariants against the
committed results).
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional

from repro.launch.ioserver import build_store, run_openloop

from .common import write_results

#: sweep cells: sessions driving the Poisson arrival stream.  The top cells
#: are deliberately far past capacity — that is where the in-flight session
#: count passes 1k and the tail collapses.
SESSIONS_SWEEP = [64, 256, 1024, 2048, 4096, 8192]
RATE_PER_SESSION = 0.35  # arrivals/s per session
DURATION_S = 2.0  # arrival window per cell
MODES = ("sync", "shared")
SEED = 7

#: a cell is *sustained* when the server kept up with the offered rate...
KNEE_ACHIEVED_FRAC = 0.9
#: ...and p99 stayed within this factor of the mode's unloaded (first-cell)
#: p99 — achieved-rate alone misses the regime where throughput still
#: matches but the queue (and the tail) has already started growing.
KNEE_P99_INFLATION = 5.0


def find_knee(cells: List[Dict]) -> Optional[Dict]:
    """The last sustained cell before the first unsustained one (cells are
    offered-rate ordered; stopping at the first failure keeps the knee
    stable when post-saturation cells wobble)."""
    if not cells:
        return None
    base_p99 = cells[0]["p99_ms"]
    knee = None
    for c in cells:
        sustained = (c["achieved_rate"] >= KNEE_ACHIEVED_FRAC
                     * c["offered_rate"]
                     and c["p99_ms"] <= KNEE_P99_INFLATION * base_p99)
        if not sustained:
            break
        knee = c
    return knee


def collect(dry_run: bool = False) -> Dict:
    sweep_sessions = [32, 96] if dry_run else SESSIONS_SWEEP
    rate = 0.5 if dry_run else RATE_PER_SESSION
    duration = 0.8 if dry_run else DURATION_S
    store = build_store()
    sweep: Dict[str, List[Dict]] = {}
    for mode in MODES:
        cells = []
        for sessions in sweep_sessions:
            rep = run_openloop(mode, sessions, rate, duration,
                               store=store, seed=SEED)
            cells.append(rep)
            print(f"# {mode} sessions={sessions} "
                  f"offered={rep['offered_rate']:.0f}/s "
                  f"achieved={rep['achieved_rate']:.0f}/s "
                  f"p99={rep['p99_ms']:.1f}ms "
                  f"inflight={rep['max_inflight_sessions']}",
                  file=sys.stderr, flush=True)
        sweep[mode] = cells

    shared_knee = find_knee(sweep["shared"])
    summary: Dict = {
        "total_sessions": sum(c["arrivals"] for cells in sweep.values()
                              for c in cells),
        "max_inflight_sessions": max(c["max_inflight_sessions"]
                                     for cells in sweep.values()
                                     for c in cells),
        "knee_sessions": {mode: (find_knee(cells) or {}).get("sessions")
                          for mode, cells in sweep.items()},
    }
    if shared_knee is not None:
        sync_at_knee = next(c for c in sweep["sync"]
                            if c["sessions"] == shared_knee["sessions"])
        summary.update({
            "knee_offered_rate": shared_knee["offered_rate"],
            "shared_p99_at_knee_ms": shared_knee["p99_ms"],
            "sync_p99_at_knee_ms": sync_at_knee["p99_ms"],
            # the acceptance number: at the shared mode's knee rate, how
            # much better is its tail than sync serving the same arrivals
            "shared_p99_speedup_at_knee":
                sync_at_knee["p99_ms"] / shared_knee["p99_ms"],
        })
    return {
        "config": {
            "sessions_sweep": sweep_sessions,
            "rate_per_session": rate,
            "duration_s": duration,
            "seed": SEED,
            "knee_achieved_frac": KNEE_ACHIEVED_FRAC,
            "knee_p99_inflation": KNEE_P99_INFLATION,
            "dry_run": dry_run,
            "methodology": "seeded Poisson arrivals, one fresh tenant "
                           "session per request, virtual-time latency from "
                           "scheduled arrival (wrk2-style), SERVE_PROFILE "
                           "simulated device",
        },
        "sweep": sweep,
        "summary": summary,
    }


def check(fresh: Dict, committed: Optional[Dict]) -> List[str]:
    """CI smoke gate.  The fresh (dry-run-sized) sweep proves the open-loop
    path works end to end — every arrival completed, no served errors, the
    in-flight accounting is coherent.  The committed full-scale results
    must still satisfy the acceptance invariants: a sweep past 1k
    concurrent sessions, a detectable shared-mode knee, and a >= 1.3x
    shared-over-sync p99 advantage at that knee."""
    errs: List[str] = []
    for mode, cells in fresh["sweep"].items():
        for c in cells:
            if c["completed"] != c["arrivals"]:
                errs.append(f"{mode}/{c['sessions']}: lost sessions "
                            f"({c['completed']}/{c['arrivals']} completed)")
            if c["errors"]:
                errs.append(f"{mode}/{c['sessions']}: {c['errors']} "
                            "serve errors")
            if c["completed"] and c["max_inflight_sessions"] < 1:
                errs.append(f"{mode}/{c['sessions']}: in-flight sweep "
                            "found no overlap at all")
    if committed is not None:
        s = committed["summary"]
        if s.get("max_inflight_sessions", 0) < 1000:
            errs.append("committed sweep never reached 1000 concurrent "
                        f"sessions (max {s.get('max_inflight_sessions')})")
        if s.get("knee_sessions", {}).get("shared") is None:
            errs.append("committed sweep shows no shared-mode saturation "
                        "knee")
        if s.get("shared_p99_speedup_at_knee", 0.0) < 1.3:
            errs.append(
                "shared p99 advantage at the knee fell below 1.3x "
                f"(committed {s.get('shared_p99_speedup_at_knee')})")
    return errs


def render_table(d: Dict) -> str:
    """docs/TUNING.md sweep table: offered rate vs achieved/p99 per mode."""
    cells = {m: {c["sessions"]: c for c in d["sweep"][m]} for m in d["sweep"]}
    sessions = [c["sessions"] for c in d["sweep"]["shared"]]
    lines = ["| sessions | offered (1/s) | sync achieved | sync p99 (ms) | "
             "shared achieved | shared p99 (ms) | peak in-flight |",
             "|---|---|---|---|---|---|---|"]
    for s in sessions:
        sy, sh = cells["sync"][s], cells["shared"][s]
        lines.append(
            f"| {s} | {sh['offered_rate']:.0f} "
            f"| {sy['achieved_rate']:.0f} | {sy['p99_ms']:.1f} "
            f"| {sh['achieved_rate']:.0f} | {sh['p99_ms']:.1f} "
            f"| {max(sy['max_inflight_sessions'], sh['max_inflight_sessions'])} |")
    return "\n".join(lines)


def run():
    """run.py section (also refreshes benchmarks/results/openloop.json)."""
    d = collect()
    write_results("openloop", d)
    s = d["summary"]
    return [
        ("openloop_shared_p99_at_knee",
         s.get("shared_p99_at_knee_ms", float("nan")) * 1e3,
         f"knee at {s.get('knee_offered_rate', 0):.0f}/s"),
        ("openloop_shared_p99_speedup_at_knee",
         s.get("shared_p99_speedup_at_knee", float("nan")),
         f"max inflight {s['max_inflight_sessions']} sessions"),
    ]


def main(argv: List[str]) -> int:
    import os

    dry = "--dry-run" in argv
    results_path = os.path.join(os.path.dirname(__file__), "results",
                                "openloop.json")
    if "--table" in argv:
        with open(results_path) as f:
            print(render_table(json.load(f)))
        return 0
    fresh = collect(dry_run=dry)
    if "--check" in argv:
        committed = None
        if os.path.exists(results_path):
            with open(results_path) as f:
                committed = json.load(f)
        errs = check(fresh, committed)
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        print(json.dumps(fresh["summary"], indent=2, sort_keys=True))
        print("openloop-smoke:", "FAIL" if errs else "ok")
        return 1 if errs else 0
    if not dry:
        write_results("openloop", fresh)
        print(f"wrote benchmarks/results/openloop.json")
    print(json.dumps(fresh["summary"], indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
