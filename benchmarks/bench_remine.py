"""Online re-mining: speculation benefit lost to LSM compaction, won back.

The endpoint is a hot-table prefix scan — K strided block reads from the
first table of the first non-empty level, with the table's fd and the scan
geometry living in app state (ctx is empty).  The mined graph can only
bake them in as constants, which makes it exactly the class of graph a
compaction invalidates: ``lsm.compact(0)`` mid-serve closes every L0
table fd and installs a new layout, so the incumbent graph's pre-issues
all miss (harvest-guard refusals + wasted completions) and the
speculation benefit drops to zero while responses stay byte-identical.

With a :class:`repro.analysis.remine.ReMiner` attached, sampled traces of
the post-compaction pattern accumulate in the bounded ring, a re-mine
attempt shadow-validates a candidate on the newest evidence window, and a
validated hot-swap restores the benefit — measured here as
``served_async / intercepted`` over speculating sessions (a counter
ratio, deterministic where wall time is not) across four phases:
fresh → stale (post-compaction) → adapting (evidence accumulating) →
recovered (post-swap), against a *freshly-mined* reference graph built
directly on the post-compaction layout.

``python -m benchmarks.bench_remine`` writes
``benchmarks/results/remine.json`` (rendered into docs/BENCHMARKS.md by
``tools/bench_report.py``); ``--dry-run --check`` is the CI remine-smoke
gate: every response byte-identical to the direct-device oracle, zero
rollbacks, and the acceptance number — recovered benefit >= 80% of the
freshly-mined reference."""

from __future__ import annotations

import json
import sys
import time
from typing import Dict, List, Optional

from repro.analysis.remine import ReMineConfig, ReMiner
from repro.core import Foreactor, io
from repro.store.lsm import LSMTree

from .bench_lsm import build_db
from .common import sim, write_results

SCAN_BYTES = 1024
SCAN_BLOCKS = 12
L0_TABLES = 6
N_KEYS = 2000
SEED = 13
PHASE_OPS = {"fresh": 24, "stale": 8, "adapting": 24, "recovered": 24}

#: the acceptance number, gated in --check against fresh and committed runs
MIN_RECOVERY_RATIO = 0.8


def _hot_table(lsm):
    for lvl in lsm.levels:
        if lvl:
            return lvl[0]
    raise RuntimeError("empty LSM tree")


def _benefit(stats: List) -> float:
    """served_async per intercepted call over speculating sessions — the
    deterministic counter form of 'fraction of I/O overlapped'.  Sampled
    (serial-recording) sessions pre-issue nothing and are excluded: they
    are the measured cost of observation, not of the graph."""
    spec = [s for s in stats if s.pre_issued > 0]
    if not spec:
        return 0.0
    return sum(s.served_async for s in spec) / max(
        1, sum(s.intercepted for s in spec))


def collect(dry_run: bool = False) -> Dict:
    n_keys = 600 if dry_run else N_KEYS
    inner, ref, _db_bytes = build_db(n_keys=n_keys, record=256,
                                     l0_tables=L0_TABLES)
    dev = sim(inner)  # BENCH_PROFILE: 16 channels, no page cache
    lsm = LSMTree.open_existing(dev, "/db")
    fa = Foreactor(device=dev, backend="io_uring", depth=32, workers=8,
                   trace_capacity=32)
    rm = ReMiner(fa, ReMineConfig(sample_every=8, min_traces=3,
                                  remine_every=3, guard_sessions=4),
                 watch=["table_scan"])

    def table_scan():
        t = _hot_table(lsm)
        return [io.pread(dev, t.fd, SCAN_BYTES, i * SCAN_BYTES)
                for i in range(SCAN_BLOCKS)]

    def oracle():
        t = _hot_table(lsm)
        return [dev.pread(t.fd, SCAN_BYTES, i * SCAN_BYTES)
                for i in range(SCAN_BLOCKS)]

    # observe → mine → install: three recorded traces trip the re-mine
    # cadence and hot-swap the first mined graph in
    for _ in range(3):
        fa.record("table_scan", {}, table_scan)

    def serve_phase(ops: int):
        stats, t0 = [], time.perf_counter()
        for _ in range(ops):
            sess = fa.activate("table_scan", {})
            try:
                got = table_scan()
            finally:
                s = fa.deactivate(sess)
            # correctness is the headline claim: byte-identical to the
            # direct-device oracle on EVERY op, across every swap boundary
            assert got == oracle(), "response diverged from sync oracle"
            assert s.pre_issued == (s.served_async + s.cancelled
                                    + s.wasted_completions), vars(s)
            stats.append(s)
        wall = time.perf_counter() - t0
        return stats, wall

    phases: List[Dict] = []
    phase_stats: Dict[str, List] = {}
    for name, ops in PHASE_OPS.items():
        if name == "stale":
            lsm.compact(0)  # the induced drift: L0 fds close, layout moves
        stats, wall = serve_phase(ops)
        phase_stats[name] = stats
        phases.append({
            "phase": name,
            "ops": ops,
            "benefit": _benefit(stats),
            "ms_per_op": wall / ops * 1e3,
            "stale_harvests": sum(s.stale_harvests for s in stats),
            "wasted": sum(s.cancelled + s.wasted_completions
                          for s in stats),
        })
        print(f"# remine phase={name} benefit={_benefit(stats):.3f} "
              f"ms/op={wall / ops * 1e3:.2f}", file=sys.stderr, flush=True)

    # reference: a graph freshly mined on the post-compaction layout —
    # the best any re-miner could hope to converge to
    fa2 = Foreactor(device=dev, backend="io_uring", depth=32, workers=8)
    for _ in range(3):
        fa2.record("table_scan", {}, table_scan)
    fa2.mine("table_scan")
    ref_stats = []
    for _ in range(PHASE_OPS["recovered"]):
        sess = fa2.activate("table_scan", {})
        try:
            got = table_scan()
        finally:
            s = fa2.deactivate(sess)
        assert got == oracle()
        ref_stats.append(s)
    benefit_ref = _benefit(ref_stats)

    snap = rm.snapshot()["endpoints"]["table_scan"]
    plan_stats = fa.plan_cache_stats()["per_graph"]["table_scan"]
    lsm.close()
    fa.shutdown()
    fa2.shutdown()

    by_phase = {p["phase"]: p for p in phases}
    recovered = by_phase["recovered"]["benefit"]
    return {
        "config": {
            "n_keys": n_keys,
            "l0_tables": L0_TABLES,
            "scan_blocks": SCAN_BLOCKS,
            "scan_bytes": SCAN_BYTES,
            "phase_ops": PHASE_OPS,
            "sample_every": 8,
            "remine_every": 3,
            "seed": SEED,
            "dry_run": dry_run,
            "methodology": "io_uring queue pair, depth 32, BENCH_PROFILE "
                           "simulated device; benefit = served_async / "
                           "intercepted over speculating sessions; drift "
                           "is lsm.compact(0) between the fresh and stale "
                           "phases; reference graph freshly mined on the "
                           "post-compaction layout",
        },
        "phases": phases,
        "remine": {
            "swaps": snap["swaps"],
            "rollbacks": snap["rollbacks"],
            "refusals": snap["refusals"],
            "samples": snap["samples"],
        },
        "plan": plan_stats,
        "summary": {
            "benefit_fresh": by_phase["fresh"]["benefit"],
            "benefit_stale": by_phase["stale"]["benefit"],
            "benefit_recovered": recovered,
            "benefit_reference": benefit_ref,
            "recovery_ratio": recovered / benefit_ref if benefit_ref else 0.0,
            "swaps": snap["swaps"],
            "rollbacks": snap["rollbacks"],
        },
    }


def check(fresh: Dict, committed: Optional[Dict]) -> List[str]:
    """CI smoke gate.  collect() itself asserts byte-identity with the
    sync oracle and the per-session ledger on every op; here we gate the
    recovery story: compaction must actually kill the benefit, the
    re-miner must win >= 80% of it back relative to a freshly-mined
    graph, and the regression guard must never have fired."""
    errs: List[str] = []
    for d in (fresh, committed) if committed is not None else (fresh,):
        tag = "fresh" if d is fresh else "committed"
        s = d["summary"]
        if s["benefit_fresh"] <= 0.5:
            errs.append(f"{tag}: fresh-phase speculation benefit "
                        f"{s['benefit_fresh']:.3f} <= 0.5 — endpoint is "
                        "not speculating to begin with")
        if s["benefit_stale"] >= 0.5 * s["benefit_fresh"]:
            errs.append(f"{tag}: compaction barely dented the benefit "
                        f"({s['benefit_stale']:.3f} vs fresh "
                        f"{s['benefit_fresh']:.3f}) — no drift induced")
        if s["recovery_ratio"] < MIN_RECOVERY_RATIO:
            errs.append(f"{tag}: recovered benefit is only "
                        f"{s['recovery_ratio']:.2f} of the freshly-mined "
                        f"reference (< {MIN_RECOVERY_RATIO})")
        if s["rollbacks"] != 0:
            errs.append(f"{tag}: regression guard rolled back "
                        f"{s['rollbacks']} swap(s) — a validated candidate "
                        "should never regress here")
        if s["swaps"] < 2:
            errs.append(f"{tag}: expected the bootstrap swap plus the "
                        f"post-drift recovery swap, saw {s['swaps']}")
    return errs


def render_table(d: Dict) -> str:
    lines = ["| phase | ops | benefit (async/intercepted) | ms/op "
             "| stale harvests | wasted |",
             "|---|---|---|---|---|---|"]
    for p in d["phases"]:
        lines.append(f"| {p['phase']} | {p['ops']} | {p['benefit']:.3f} "
                     f"| {p['ms_per_op']:.2f} | {p['stale_harvests']} "
                     f"| {p['wasted']} |")
    s = d["summary"]
    lines.append(f"| reference (fresh mine) | {d['config']['phase_ops']['recovered']} "
                 f"| {s['benefit_reference']:.3f} | — | — | — |")
    return "\n".join(lines)


def run():
    """run.py section (also refreshes benchmarks/results/remine.json)."""
    d = collect()
    write_results("remine", d)
    s = d["summary"]
    by_phase = {p["phase"]: p for p in d["phases"]}
    return [
        ("remine_recovered_ms_per_op", by_phase["recovered"]["ms_per_op"],
         f"recovery_ratio={s['recovery_ratio']:.2f}"),
        ("remine_stale_ms_per_op", by_phase["stale"]["ms_per_op"],
         f"benefit={s['benefit_stale']:.2f}"),
    ]


def main(argv: List[str]) -> int:
    import os

    dry = "--dry-run" in argv
    results_path = os.path.join(os.path.dirname(__file__), "results",
                                "remine.json")
    if "--table" in argv:
        with open(results_path) as f:
            print(render_table(json.load(f)))
        return 0
    fresh = collect(dry_run=dry)
    if "--check" in argv:
        committed = None
        if os.path.exists(results_path):
            with open(results_path) as f:
                committed = json.load(f)
        errs = check(fresh, committed)
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        print(json.dumps(fresh["summary"], indent=2, sort_keys=True))
        print("remine-smoke:", "FAIL" if errs else "ok")
        return 1 if errs else 0
    if not dry:
        write_results("remine", fresh)
        print("wrote benchmarks/results/remine.json")
    print(json.dumps(fresh["summary"], indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
