"""Raw-device bandwidth: direct-I/O lanes + extent coalescing on the two
storage-heavy consumers.

The sharding section (bench_sharding) showed the *op-rate* story: per-device
queue pairs fan pre-issued requests across shards and aggregate IOPS scale
with device count.  But every request still pays ``base_latency`` per
*extent*, so small-extent workloads top out at a tiny fraction of what the
device can stream.  This section measures what the bandwidth-oriented path
buys (docs/ARCHITECTURE.md, "Direct I/O & extent coalescing"):

* **alignment-classed buffers** — PREAD leases come from 512/4096-aligned
  mmap slabs (``repro.core.buffers.BufferPool``) so they are valid
  O_DIRECT targets (the READ_FIXED analogue);
* **extent coalescing** — the dispatch path fuses statically-adjacent
  same-fd pread runs into MB-scale super-reads
  (``repro.core.coalesce.ExtentCoalescer``), amortizing ``base_latency``
  over the whole run and scattering zero-copy sub-views on completion;
* **direct lanes** — ``direct=True`` devices bypass the simulated page
  cache and demand aligned targets, as an O_DIRECT fd does.

Sweeps: 1-8 shards x {buffered, direct} x {coalesce off, on} on a simulated
NVMe-class profile, for

* **restore** — ``CheckpointManager.restore`` of a checkpoint whose chunks
  are sorted into per-shard-file adjacent runs, and
* **pipeline** — ``TokenBatchLoader`` in ``sequential`` streaming order
  (``repro.data.pipeline.DataConfig``), where consecutive records of a
  shard are byte-adjacent.

Every row reports ``bandwidth_mb_s`` and ``raw_fraction`` — the fraction of
``n_devices * DeviceProfile.raw_bandwidth_bytes()`` actually achieved.

Results land in ``benchmarks/results/bandwidth.json`` (common.write_results
conventions; table rendered into docs/BENCHMARKS.md by
``tools/bench_report.py``).  ``python -m benchmarks.bench_bandwidth
--dry-run --check`` is the CI bandwidth-smoke gate: a reduced sweep proves
the fused path end to end (restored bytes asserted identical inline), and
the committed full-scale results must satisfy the acceptance invariants —
coalesced+direct pipeline bandwidth >= 5x the committed sharding.json
io_uring pipeline baseline, and coalesced+direct restore bandwidth at
4 shards >= 2.5x the 1-shard figure.
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import DeviceProfile, Foreactor, ShardedDevice
from repro.data import (DataConfig, ShardedTokenDataset, TokenBatchLoader,
                        write_synthetic_dataset)

from .common import Row, timeit_min, write_results

SHARD_COUNTS = (1, 2, 4, 8)

#: (label, direct, coalesce) — ``buffered`` with coalescing off is the
#: pre-existing per-extent path; ``direct_coalesced`` is the full
#: bandwidth-oriented lane.
MODES = (
    ("buffered", False, False),
    ("buffered_coalesced", False, True),
    ("direct", True, False),
    ("direct_coalesced", True, True),
)

#: NVMe-class *shape* at CI-measurable time constants (see
#: repro.core.device.NVME_PROFILE for why the literal 60 us profile is
#: unmeasurable under Python sleep granularity): ms-scale per-op command
#: cost that dominates small extents (a 16 KiB record costs 4.4 ms, of
#: which 0.4 ms is streaming — the gap coalescing closes), one channel per
#: device so aggregate bandwidth scales with *device* count, and a
#: streaming rate chosen so full super-read waves stay an order of
#: magnitude above the harness's Python memcpy overhead (~2.5 ms/MiB).
CHANNELS = 1
BW_PROFILE = DeviceProfile(channels=CHANNELS, base_latency=4.0e-3,
                           per_byte=2.5e-8, crossing_cost=4e-6,
                           metadata_latency=1.0e-3)

#: restore: 16 MiB tree in 256 KiB chunks round-robined over one shard
#: file per device; sorted into per-fd adjacent runs they fuse into
#: 4 MiB super-reads (4 total).  One single-channel device serializes
#: them in 4 waves; 4 devices finish in one.
CHUNK_BYTES = 256 << 10
NUM_CHUNKS = 64

#: pipeline: 16 KiB records, 16 records per shard file => a sequential
#: 64-record batch covers 4 shard files on 4 devices, each file one
#: 256 KiB adjacent run.
PIPE_SEQ_LEN = 4095
PIPE_BATCH = 64
PIPE_RECORDS_PER_SHARD = 16
PIPE_NUM_SHARDS = 48


def _sharded(n: int, direct: bool) -> ShardedDevice:
    return ShardedDevice.simulated(n, profile=BW_PROFILE, direct=direct)


def _raw_fraction(bw_bytes_s: float, n: int) -> float:
    return bw_bytes_s / (n * BW_PROFILE.raw_bandwidth_bytes())


def bench_restore(shard_counts: Sequence[int] = SHARD_COUNTS,
                  modes: Sequence[Tuple] = MODES,
                  num_chunks: int = NUM_CHUNKS,
                  repeats: int = 2) -> Dict[str, Dict]:
    """Checkpoint restore bandwidth vs shard count per I/O mode."""
    tree = {"w": np.arange((CHUNK_BYTES // 4) * num_chunks,
                           dtype=np.float32)}
    nbytes = tree["w"].nbytes
    out: Dict[str, Dict] = {"config": {
        "shard_counts": list(shard_counts), "chunk_bytes": CHUNK_BYTES,
        "num_chunks": num_chunks, "channels_per_device": CHANNELS,
        "modes": [m[0] for m in modes],
    }}
    for n in shard_counts:
        for direct in sorted({d for _l, d, _c in modes}):
            dev = _sharded(n, direct)
            # write once per (topology, lane) with a placement-only
            # manager, then shut its pools down so they don't linger into
            # the timings
            mgr0 = CheckpointManager(dev, "/ck", num_shards=n,
                                     chunk_bytes=CHUNK_BYTES, keep=2)
            mgr0.save(1, tree)
            mgr0.fa.shutdown()
            for label, d, coalesce in modes:
                if d != direct:
                    continue
                fa = Foreactor(device=dev, backend="multi_queue",
                               depth=2 * num_chunks, workers=4,
                               coalesce=coalesce)
                mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=n,
                                        chunk_bytes=CHUNK_BYTES, keep=2)
                # conformance inline: the fused/direct path must hand back
                # the exact bytes the per-extent sync path wrote
                got, _extra = mgr.restore(1, check_crc=False)
                (leaf,) = got.values()  # single-leaf tree, keypath-named
                assert np.array_equal(leaf, tree["w"]), \
                    f"restore mismatch in mode {label} at {n} shards"
                t = timeit_min(lambda: mgr.restore(1, check_crc=False),
                               repeats=repeats, warmup=0)
                fa.shutdown()
                bw = nbytes / t
                out.setdefault(label, {})[str(n)] = {
                    "seconds": t,
                    "bandwidth_mb_s": bw / 1e6,
                    "raw_fraction": _raw_fraction(bw, n),
                }
    for label, _d, _c in modes:
        cells = out[label]
        lo, hi = str(min(shard_counts)), str(max(shard_counts))
        if "1" in cells and "4" in cells:
            out[f"scaling_4shards_{label}"] = (
                cells["4"]["bandwidth_mb_s"] / cells["1"]["bandwidth_mb_s"])
        out[f"coalesce_speedup_{label}_{lo}sh"] = None  # filled below
    for direct_label, base_label in (("direct_coalesced", "direct"),
                                     ("buffered_coalesced", "buffered")):
        if direct_label in out and base_label in out:
            for n in shard_counts:
                k = f"coalesce_speedup_{direct_label}_{n}sh"
                out[k] = (out[direct_label][str(n)]["bandwidth_mb_s"]
                          / out[base_label][str(n)]["bandwidth_mb_s"])
    # drop the placeholder keys never filled
    for k in [k for k, v in out.items() if v is None]:
        del out[k]
    return out


def bench_pipeline(shard_counts: Sequence[int] = SHARD_COUNTS,
                   modes: Sequence[Tuple] = MODES,
                   batches: int = 2) -> Dict[str, Dict]:
    """Sequential-order TokenBatchLoader bandwidth vs shard count per mode.

    ``DataConfig(sequential=True)`` streams records in storage order, so a
    batch's extents form same-fd adjacent runs the coalescer can fuse; the
    double-buffer keeps the next batch's super-reads in flight during this
    batch's numpy work (same warmup discipline as bench_sharding)."""
    cfg = DataConfig(seq_len=PIPE_SEQ_LEN, batch_size=PIPE_BATCH,
                     sequential=True)
    out: Dict[str, Dict] = {"config": {
        "shard_counts": list(shard_counts), "batch_size": cfg.batch_size,
        "record_bytes": cfg.record_bytes, "batches": batches,
        "records_per_shard": PIPE_RECORDS_PER_SHARD,
        "num_shard_files": PIPE_NUM_SHARDS,
        "modes": [m[0] for m in modes],
    }}
    for n in shard_counts:
        for direct in sorted({d for _l, d, _c in modes}):
            dev = _sharded(n, direct)
            paths = write_synthetic_dataset(
                dev, "/data", cfg, num_shards=PIPE_NUM_SHARDS,
                records_per_shard=PIPE_RECORDS_PER_SHARD, vocab_size=1000)
            for label, d, coalesce in modes:
                if d != direct:
                    continue
                ds = ShardedTokenDataset(dev, paths)
                fa = Foreactor(device=dev, backend="multi_queue",
                               depth=2 * cfg.batch_size, workers=4,
                               coalesce=coalesce)
                loader = TokenBatchLoader(ds, cfg, fa=fa)
                state = {"step": 0}

                def run_batches():
                    for _ in range(batches):
                        loader.load(0, state["step"])
                        state["step"] += 1

                t = timeit_min(run_batches, repeats=2)
                loader.close()
                ds.close()
                fa.shutdown()
                nbytes = batches * cfg.batch_size * cfg.record_bytes
                bw = nbytes / t
                out.setdefault(label, {})[str(n)] = {
                    "seconds": t,
                    "bandwidth_mb_s": bw / 1e6,
                    "raw_fraction": _raw_fraction(bw, n),
                }
    for label, _d, _c in modes:
        cells = out[label]
        best = max(c["bandwidth_mb_s"] for c in cells.values()
                   if isinstance(c, dict))
        out[f"best_mb_s_{label}"] = best
    return out


def collect(dry_run: bool = False) -> Dict[str, Dict]:
    if dry_run:
        modes = (MODES[0], MODES[3])  # buffered vs direct_coalesced
        restore = bench_restore(shard_counts=(1, 4), modes=modes,
                                num_chunks=16, repeats=1)
        pipeline = bench_pipeline(shard_counts=(1, 4), modes=modes,
                                  batches=1)
    else:
        restore = bench_restore()
        pipeline = bench_pipeline()
    return {"restore": restore, "pipeline": pipeline}


def _sharding_io_uring_baseline() -> Optional[float]:
    """Best committed io_uring pipeline bandwidth from sharding.json."""
    import os
    path = os.path.join(os.path.dirname(__file__), "results",
                        "sharding.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        committed = json.load(f)
    cells = committed.get("pipeline", {}).get("io_uring", {})
    vals = [c["bandwidth_mb_s"] for c in cells.values()
            if isinstance(c, dict)]
    return max(vals) if vals else None


def check(fresh: Dict, committed: Optional[Dict]) -> List[str]:
    """CI smoke gate.  The fresh (dry-run-sized) sweep proves the fused
    direct path end to end (restores byte-identical — asserted inline —
    and every timing positive); the committed full-scale results must
    satisfy the acceptance invariants: coalesced+direct pipeline >= 5x the
    committed sharding.json io_uring pipeline baseline, and
    coalesced+direct restore at 4 shards >= 2.5x the 1-shard figure."""
    errs: List[str] = []
    for section in ("restore", "pipeline"):
        for label in fresh[section]["config"]["modes"]:
            for n, cell in fresh[section][label].items():
                if cell["seconds"] <= 0:
                    errs.append(f"{section} {label}/{n}: non-positive time")
    if committed is not None:
        scaling = committed["restore"].get("scaling_4shards_direct_coalesced")
        if scaling is None or scaling < 2.5:
            errs.append("committed direct_coalesced restore scaling at "
                        f"4 shards fell below 2.5x ({scaling})")
        baseline = _sharding_io_uring_baseline()
        best = committed["pipeline"].get("best_mb_s_direct_coalesced")
        if baseline is not None:
            if best is None or best < 5.0 * baseline:
                errs.append("committed direct_coalesced pipeline bandwidth "
                            f"({best} MB/s) is not >= 5x the sharding.json "
                            f"io_uring baseline ({baseline} MB/s)")
    return errs


def run() -> List[Row]:
    d = collect()
    restore, pipeline = d["restore"], d["pipeline"]
    path = write_results("bandwidth", d)
    rows: List[Row] = []
    for section, data in (("restore", restore), ("pipeline", pipeline)):
        for label, _d, _c in MODES:
            for n in data["config"]["shard_counts"]:
                cell = data[label][str(n)]
                rows.append((
                    f"bandwidth_{section}_{label}_sh{n}",
                    cell["seconds"] * 1e6,
                    f"bw={cell['bandwidth_mb_s']:.1f}MB/s "
                    f"raw={cell['raw_fraction'] * 100:.0f}%",
                ))
    rows.append(("bandwidth_restore_scaling_4sh_direct_coalesced", 0.0,
                 f"x{restore['scaling_4shards_direct_coalesced']:.2f}"))
    baseline = _sharding_io_uring_baseline()
    if baseline:
        rows.append(("bandwidth_pipeline_vs_sharding_io_uring", 0.0,
                     f"x{pipeline['best_mb_s_direct_coalesced'] / baseline:.1f}"))
    rows.append(("bandwidth_results_json", 0.0, path))
    return rows


def main(argv: List[str]) -> int:
    import os

    dry = "--dry-run" in argv
    fresh = collect(dry_run=dry)
    if "--check" in argv:
        results_path = os.path.join(os.path.dirname(__file__), "results",
                                    "bandwidth.json")
        committed = None
        if os.path.exists(results_path):
            with open(results_path) as f:
                committed = json.load(f)
        errs = check(fresh, committed)
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        print("bandwidth-smoke:", "FAIL" if errs else "ok")
        return 1 if errs else 0
    if not dry:
        write_results("bandwidth", fresh)
        print("wrote benchmarks/results/bandwidth.json")
    summary = {
        "restore_scaling_4shards_direct_coalesced":
            fresh["restore"].get("scaling_4shards_direct_coalesced"),
        "pipeline_best_mb_s_direct_coalesced":
            fresh["pipeline"].get("best_mb_s_direct_coalesced"),
        "sharding_io_uring_baseline_mb_s": _sharding_io_uring_baseline(),
    }
    print(json.dumps(summary, indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
