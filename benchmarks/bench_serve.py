"""Multi-tenant serving benchmark: shared-backend scheduler vs per-thread
isolation vs no speculation (docs/TUNING.md, docs/ARCHITECTURE.md
"Shared-backend scheduling").

Two experiments over the closed-loop server in ``repro.launch.ioserver``:

* **concurrency sweep** — N get clients (N in ``CLIENT_COUNTS``) × modes
  {sync, isolated, shared}: per-mode p50/p99 latency and aggregate
  throughput.  Headline checks (written to ``summary``):
  ``shared_beats_sync_p99`` at the highest concurrency, and
  ``shared_tput_vs_isolated`` within ~10% (the price of arbitration).
* **priority mix** — 4 high-priority get clients alone vs the same 4 plus
  4 low-priority checkpoint-restore clients flooding the pool with
  speculation.  Headline: ``high_pri_p99_delta`` ≤ ~10% — weighted-fair
  admission + pressure eviction keep the hot tenants' tail flat.

Every cell is best-of-``REPEATS`` (min per metric) to filter 2-vCPU CI
scheduler noise.  Results land in ``benchmarks/results/serve.json``;
``python -m benchmarks.bench_serve --table`` renders the markdown table
embedded in docs/TUNING.md.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

from repro.launch.ioserver import (build_store, get_clients, restore_clients,
                                   run_serving)

from .common import RESULTS_DIR, Row, write_results

CLIENT_COUNTS = (2, 8)
MODES = ("sync", "isolated", "shared")
#: best-of-N per cell: 2-vCPU CI boxes show 2x wall-time noise between
#: identical runs; the min/max aggregation converges on true capability
REPEATS = 4
OPS = 30
HIGH_CLIENTS = 4
LOW_RESTORES = 4
#: the priority-mix comparison is a p99-vs-p99 delta — the most
#: noise-sensitive number in the file — so it gets more samples per run
#: and more repeats than the sweep cells
PRIORITY_OPS = 60
PRIORITY_REPEATS = 6


def _best_of(mode: str, clients, store, repeats: int = REPEATS) -> dict:
    """Run one config ``repeats`` times; keep per-metric minima (latency,
    wall) / maxima (throughput) plus the last run's scheduler snapshot."""
    runs = [run_serving(mode, clients, store=store) for _ in range(repeats)]
    best = dict(runs[-1])
    for r in runs:
        assert r["errors"] == 0, f"{mode}: {r['errors']} serving errors"
    def agg(metric, cls):
        return min(r["classes"][cls][metric] for r in runs if cls in r["classes"])
    classes = runs[-1]["classes"]
    best["classes"] = {
        cls: {"ops": classes[cls]["ops"],
              "p50_ms": agg("p50_ms", cls), "p99_ms": agg("p99_ms", cls)}
        for cls in classes
    }
    best["throughput_ops"] = max(r["throughput_ops"] for r in runs)
    best["wall_s"] = min(r["wall_s"] for r in runs)
    best.pop("per_client", None)  # keep the JSON small; classes suffice
    return best


def bench() -> Dict[str, dict]:
    store = build_store()
    out: Dict[str, dict] = {"config": {
        "client_counts": list(CLIENT_COUNTS), "modes": list(MODES),
        "repeats": REPEATS, "ops_per_client": OPS,
        "high_clients": HIGH_CLIENTS, "low_restores": LOW_RESTORES,
    }}

    # -- concurrency sweep ----------------------------------------------------
    sweep: Dict[str, dict] = {}
    for n in CLIENT_COUNTS:
        cell: Dict[str, dict] = {}
        for mode in MODES:
            cell[mode] = _best_of(mode, get_clients(n, priority="high",
                                                    ops=OPS), store)
        sweep[str(n)] = cell
    out["sweep"] = sweep

    # -- priority mix on the shared scheduler ---------------------------------
    high = get_clients(HIGH_CLIENTS, priority="high", ops=PRIORITY_OPS,
                       prefix="hot")
    base = _best_of("shared", high, store, repeats=PRIORITY_REPEATS)
    loaded = _best_of("shared", high + restore_clients(LOW_RESTORES), store,
                      repeats=PRIORITY_REPEATS)
    out["priority_mix"] = {"high_only": base, "with_low_pri_load": loaded}

    # -- summary --------------------------------------------------------------
    top = str(max(CLIENT_COUNTS))
    sync_p99 = sweep[top]["sync"]["classes"]["high"]["p99_ms"]
    shared_p99 = sweep[top]["shared"]["classes"]["high"]["p99_ms"]
    iso_tput = sweep[top]["isolated"]["throughput_ops"]
    shared_tput = sweep[top]["shared"]["throughput_ops"]
    hp_base = base["classes"]["high"]["p99_ms"]
    hp_loaded = loaded["classes"]["high"]["p99_ms"]
    out["summary"] = {
        "clients": int(top),
        "sync_p99_ms": sync_p99,
        "shared_p99_ms": shared_p99,
        "shared_beats_sync_p99": shared_p99 < sync_p99,
        "shared_p99_speedup": sync_p99 / shared_p99,
        "isolated_tput_ops": iso_tput,
        "shared_tput_ops": shared_tput,
        "shared_tput_vs_isolated": shared_tput / iso_tput,
        "shared_tput_within_10pct": shared_tput >= 0.90 * iso_tput,
        "high_pri_p99_base_ms": hp_base,
        "high_pri_p99_loaded_ms": hp_loaded,
        "high_pri_p99_delta": hp_loaded / hp_base - 1.0,
        "high_pri_p99_stable": hp_loaded <= 1.10 * hp_base,
        "loaded_scheduler": loaded.get("scheduler"),
    }
    return out


def run() -> List[Row]:
    out = bench()
    path = write_results("serve", out)
    rows: List[Row] = []
    for n, cell in out["sweep"].items():
        for mode, rep in cell.items():
            c = rep["classes"]["high"]
            rows.append((
                f"serve_{mode}_{n}clients", c["p50_ms"] * 1e3,
                f"p99={c['p99_ms']:.1f}ms tput={rep['throughput_ops']:.0f}ops",
            ))
    s = out["summary"]
    rows.append((
        "serve_summary", 0.0,
        f"shared_vs_sync_p99=x{s['shared_p99_speedup']:.2f} "
        f"tput_vs_isolated={s['shared_tput_vs_isolated']:.2f} "
        f"high_pri_delta={s['high_pri_p99_delta']*100:+.1f}%",
    ))
    rows.append(("serve_results_json", 0.0, path))
    return rows


def render_table(path: str = None) -> str:
    """The markdown table embedded in docs/TUNING.md, generated from the
    benchmark's JSON results."""
    path = path or os.path.join(RESULTS_DIR, "serve.json")
    with open(path) as f:
        data = json.load(f)
    lines = [
        "| clients | mode | p50 | p99 | throughput |",
        "|---|---|---|---|---|",
    ]
    for n, cell in sorted(data["sweep"].items(), key=lambda kv: int(kv[0])):
        for mode in data["config"]["modes"]:
            c = cell[mode]["classes"]["high"]
            lines.append(
                f"| {n} | {mode} | {c['p50_ms']:.1f} ms | {c['p99_ms']:.1f} ms"
                f" | {cell[mode]['throughput_ops']:.0f} op/s |")
    s = data["summary"]
    lines.append("")
    lines.append(
        f"High-priority p99 with 4 low-priority restore tenants added: "
        f"{s['high_pri_p99_base_ms']:.1f} ms → {s['high_pri_p99_loaded_ms']:.1f} ms "
        f"({s['high_pri_p99_delta']*100:+.1f}%).")
    return "\n".join(lines)


if __name__ == "__main__":
    if "--table" in sys.argv:
        print(render_table())
    else:
        for line in run():
            print(",".join(str(x) for x in line))
