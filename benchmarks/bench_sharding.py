"""Multi-device scaling: restore / data-pipeline throughput vs device count.

The sharded substrate's claim (docs/ARCHITECTURE.md, "Sharded multi-device
substrate"): with one queue pair per sub-device, a single ``submit_all``
crossing fans a pre-issued batch across N devices and aggregate bandwidth
approaches ``sum(BW_i)``.  This section measures it on the two storage-heavy
consumers:

* **restore** — ``CheckpointManager.restore`` of a striped checkpoint whose
  shard files live on distinct sub-devices;
* **pipeline** — ``TokenBatchLoader`` batches over record shards placed on
  distinct sub-devices.

Baselines per device count: ``sync`` (no speculation), ``io_uring`` (one
queue pair for the whole sharded device, worker pool sized like one device's
queue pair) and ``multi_queue`` (one queue pair per device).  Each simulated
device has ``CHANNELS``-way internal parallelism, so the single queue pair
saturates at one device's concurrency while per-device queue pairs scale.

Results go to ``benchmarks/results/sharding.json`` (common.write_results
conventions); the headline figure is ``restore.speedup_multi_queue_4dev`` —
aggregate restore bandwidth at 4 devices over 1 device, expected >= 2.5x.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import (DeviceProfile, Foreactor, MemDevice, ShardedDevice,
                        SimulatedDevice, io)
from repro.checkpoint import CheckpointManager
from repro.data import (DataConfig, ShardedTokenDataset, TokenBatchLoader,
                        write_synthetic_dataset)

from .common import Row, timeit_min, write_results

DEVICE_COUNTS = (1, 2, 4, 8)
BACKENDS = ("sync", "io_uring", "multi_queue")

#: per-device profile: few channels and ms-scale latency so that (a) one
#: device saturates quickly and (b) Python sleep granularity (~1 ms floor in
#: CI containers) cannot blur the effect.  A queue pair's io_workqueue is
#: sized to its device's channels.
CHANNELS = 4
SHARD_PROFILE = DeviceProfile(channels=CHANNELS, base_latency=4.0e-3,
                              per_byte=1.0e-9, crossing_cost=4e-6,
                              metadata_latency=1.0e-3)


def _sharded(n: int) -> ShardedDevice:
    return ShardedDevice.simulated(n, profile=SHARD_PROFILE)


def _restore_bytes(mgr: CheckpointManager, step: int) -> int:
    m = mgr.read_manifest(step)
    return sum(leaf["nbytes"] for leaf in m["leaves"])


def bench_restore(chunk_bytes: int = 64 * 1024, num_files: int = 96,
                  repeats: int = 2) -> Dict[str, Dict]:
    """Checkpoint restore bandwidth vs device count per backend."""
    tree = {"w": np.arange((chunk_bytes // 4) * num_files,
                           dtype=np.float32)}  # num_files chunks of chunk_bytes
    out: Dict[str, Dict] = {"config": {
        "device_counts": list(DEVICE_COUNTS), "chunk_bytes": chunk_bytes,
        "num_extents": num_files, "channels_per_device": CHANNELS,
    }}
    for n in DEVICE_COUNTS:
        dev = _sharded(n)
        # write once per topology with a fast manager (placement only);
        # shut its worker pools down so they don't linger into the timings
        mgr0 = CheckpointManager(dev, "/ck", num_shards=num_files,
                                 chunk_bytes=chunk_bytes, keep=2)
        mgr0.save(1, tree)
        nbytes = _restore_bytes(mgr0, 1)
        mgr0.fa.shutdown()
        for backend in BACKENDS:
            fa = Foreactor(device=dev, backend=backend, depth=2 * num_files,
                           workers=CHANNELS)
            mgr = CheckpointManager(dev, "/ck", fa=fa, num_shards=num_files,
                                    chunk_bytes=chunk_bytes, keep=2)
            # warmup amortizes queue-pair setup; the serial sync baseline has
            # negligible variance, one unwarmed run is enough
            t = timeit_min(lambda: mgr.restore(1, check_crc=False),
                           repeats=1 if backend == "sync" else repeats,
                           warmup=0 if backend == "sync" else 1)
            fa.shutdown()
            out.setdefault(backend, {})[str(n)] = {
                "seconds": t,
                "bandwidth_mb_s": nbytes / t / 1e6,
            }
    mq = out["multi_queue"]
    out["speedup_multi_queue_4dev"] = (
        mq["4"]["bandwidth_mb_s"] / mq["1"]["bandwidth_mb_s"])
    out["speedup_multi_queue_8dev"] = (
        mq["8"]["bandwidth_mb_s"] / mq["1"]["bandwidth_mb_s"])
    return out


def bench_pipeline(batches: int = 2) -> Dict[str, Dict]:
    """TokenBatchLoader steady-state throughput vs device count per backend.

    A warmup pass fills the double-buffer and builds the per-thread queue
    pairs; the timed pass then measures the pipeline as a trainer sees it
    mid-epoch (each timed ``load`` continues from the warmup's step counter
    so the prefetch pipeline stays hot)."""
    cfg = DataConfig(seq_len=255, batch_size=64)  # 1 KiB records
    out: Dict[str, Dict] = {"config": {
        "device_counts": list(DEVICE_COUNTS), "batch_size": cfg.batch_size,
        "record_bytes": cfg.record_bytes, "batches": batches,
    }}
    for n in DEVICE_COUNTS:
        dev = _sharded(n)
        paths = write_synthetic_dataset(dev, "/data", cfg, num_shards=16,
                                        records_per_shard=40, vocab_size=1000)
        for backend in BACKENDS:
            ds = ShardedTokenDataset(dev, paths)
            fa = Foreactor(device=dev, backend=backend,
                           depth=2 * cfg.batch_size, workers=CHANNELS)
            loader = TokenBatchLoader(ds, cfg, fa=fa,
                                      prefetch=(backend != "sync"))
            state = {"step": 0}

            def run_batches():
                for _ in range(batches):
                    loader.load(0, state["step"])
                    state["step"] += 1

            t = timeit_min(run_batches, repeats=2)
            loader.close()
            ds.close()
            fa.shutdown()
            nbytes = batches * cfg.batch_size * cfg.record_bytes
            out.setdefault(backend, {})[str(n)] = {
                "seconds": t,
                "bandwidth_mb_s": nbytes / t / 1e6,
            }
    mq = out["multi_queue"]
    out["speedup_multi_queue_4dev"] = (
        mq["4"]["bandwidth_mb_s"] / mq["1"]["bandwidth_mb_s"])
    return out


def run() -> List[Row]:
    restore = bench_restore()
    pipeline = bench_pipeline()
    path = write_results("sharding", {"restore": restore, "pipeline": pipeline})
    rows: List[Row] = []
    for section, data in (("restore", restore), ("pipeline", pipeline)):
        for backend in BACKENDS:
            for n in DEVICE_COUNTS:
                cell = data[backend][str(n)]
                rows.append((
                    f"sharding_{section}_{backend}_dev{n}",
                    cell["seconds"] * 1e6,
                    f"bw={cell['bandwidth_mb_s']:.1f}MB/s",
                ))
    rows.append(("sharding_restore_speedup_4dev",
                 0.0, f"x{restore['speedup_multi_queue_4dev']:.2f}"))
    rows.append(("sharding_results_json", 0.0, path))
    return rows


if __name__ == "__main__":
    for line in run():
        print(line)
