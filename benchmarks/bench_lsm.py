"""Paper Fig. 8 + Fig. 9: LevelDB-style Get under explicit speculation.

* Fig. 8(a): average Get latency vs page-cache memory ratio.
* Fig. 8(b): vs record (value) size.
* Fig. 8(c): p99 tail latency.
* Fig. 9(a): multiple client threads.
* Fig. 9(b): read/write operation mix.
* Fig. 9(c): Zipf skew sweep.
"""

from __future__ import annotations

import threading
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core import Foreactor, MemDevice
from repro.store import plugins
from repro.store.lsm import LSMTree

from .common import Row, sim, timeit, zipf_keys


def build_db(n_keys: int = 4000, record: int = 256, l0_tables: int = 10
             ) -> Tuple[MemDevice, dict, int]:
    """Unique keys written once in random order across many overlapping L0
    tables -> Get search chains of ~l0_tables candidates (paper's 12~19)."""
    rng = np.random.default_rng(0)
    inner = MemDevice()
    per_table = n_keys // l0_tables
    limit = per_table * (record + 12)
    lsm = LSMTree(inner, "/db", memtable_limit_bytes=limit, l0_limit=10**6,
                  fsync_writes=False)
    ref = {}
    payload = rng.bytes(record)
    for k in rng.permutation(n_keys):
        v = int(k).to_bytes(8, "little") + payload[:-8]
        lsm.put(int(k), v)
        ref[int(k)] = v
    lsm.flush()
    db_bytes = sum(t.size_bytes for lvl in lsm.levels for t in lvl)
    lsm.close()
    return inner, ref, db_bytes


def _gets(lsm, keys, ref=None):
    for k in keys:
        v = lsm.get(int(k))
        if ref is not None:
            assert v == ref[int(k)]


def bench_memory_ratio(ratios=(0.05, 0.33, 0.66), n_ops: int = 60) -> List[Row]:
    inner, ref, db_bytes = build_db()
    rng = np.random.default_rng(1)
    keys = zipf_keys(4000, n_ops, 0.99, rng)
    rows: List[Row] = []
    for ratio in ratios:
        cache = int(db_bytes * ratio)
        for use_fa, label in ((False, "sync"), (True, "foreactor")):
            dev = sim(inner, cache_bytes=cache)
            lsm = LSMTree.open_existing(dev, "/db")
            if use_fa:
                fa = Foreactor(device=dev, backend="io_uring", depth=16)
                plugins.register_all(fa)
                get = fa.wrap("lsm_get", plugins.capture_lsm_get)(
                    lambda l, k: l.get(k))
                t = timeit(lambda: [get(lsm, int(k)) for k in keys]) / n_ops
                fa.shutdown()
            else:
                t = timeit(lambda: _gets(lsm, keys, ref)) / n_ops
            rows.append((f"lsm_get_mem{int(ratio*100)}pct_{label}", t * 1e6, ""))
            lsm.close()
        s, f = rows[-2][1], rows[-1][1]
        rows[-1] = (rows[-1][0], f, f"improvement={100*(1-f/s):.0f}%")
    return rows


def bench_record_size(records=(64, 1024, 4096), n_ops: int = 50) -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(2)
    for rec in records:
        inner, ref, db_bytes = build_db(n_keys=2000, record=rec)
        keys = zipf_keys(2000, n_ops, 0.99, rng)
        lat = {}
        for use_fa, label in ((False, "sync"), (True, "foreactor")):
            dev = sim(inner, cache_bytes=db_bytes // 10)
            lsm = LSMTree.open_existing(dev, "/db")
            if use_fa:
                fa = Foreactor(device=dev, backend="io_uring", depth=16)
                plugins.register_all(fa)
                get = fa.wrap("lsm_get", plugins.capture_lsm_get)(
                    lambda l, k: l.get(k))
                per = []
                for k in keys:
                    t0 = time.perf_counter()
                    get(lsm, int(k))
                    per.append(time.perf_counter() - t0)
                fa.shutdown()
            else:
                per = []
                for k in keys:
                    t0 = time.perf_counter()
                    lsm.get(int(k))
                    per.append(time.perf_counter() - t0)
            lat[label] = per
            lsm.close()
        mean_s = np.mean(lat["sync"]); mean_f = np.mean(lat["foreactor"])
        p99_s = np.percentile(lat["sync"], 99); p99_f = np.percentile(lat["foreactor"], 99)
        rows.append((f"lsm_get_rec{rec}B_sync", mean_s * 1e6,
                     f"p99_us={p99_s*1e6:.0f}"))
        rows.append((f"lsm_get_rec{rec}B_foreactor", mean_f * 1e6,
                     f"p99_us={p99_f*1e6:.0f};improvement={100*(1-mean_f/mean_s):.0f}%"))
    return rows


def bench_clients(counts=(1, 2, 4), n_ops: int = 40) -> List[Row]:
    """Fig. 9(a): each client thread speculates independently."""
    inner, ref, db_bytes = build_db(n_keys=2000)
    rows: List[Row] = []
    for nc in counts:
        dev = sim(inner, cache_bytes=db_bytes // 10)
        fa = Foreactor(device=dev, backend="io_uring", depth=16)
        plugins.register_all(fa)
        lsm = LSMTree.open_existing(dev, "/db")
        get = fa.wrap("lsm_get", plugins.capture_lsm_get)(lambda l, k: l.get(k))

        def client(cid):
            rng = np.random.default_rng(cid)
            for k in zipf_keys(2000, n_ops, 0.99, rng):
                get(lsm, int(k))

        t0 = time.perf_counter()
        ts = [threading.Thread(target=client, args=(i,)) for i in range(nc)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        dt = time.perf_counter() - t0
        total = nc * n_ops
        rows.append((f"lsm_get_clients{nc}", dt / total * 1e6,
                     f"ops_per_s={total/dt:.0f}"))
        lsm.close()
        fa.shutdown()
    return rows


def bench_op_mix(get_fracs=(1.0, 0.5), n_ops: int = 60) -> List[Row]:
    """Fig. 9(b): only Gets are accelerated; improvement scales with the
    Get fraction."""
    rows: List[Row] = []
    for frac in get_fracs:
        inner, ref, db_bytes = build_db(n_keys=2000)
        rng = np.random.default_rng(3)
        keys = zipf_keys(2000, n_ops, 0.99, rng)
        ops = rng.random(n_ops) < frac  # True = get, False = put
        for use_fa, label in ((False, "sync"), (True, "foreactor")):
            dev = sim(inner, cache_bytes=db_bytes // 10)
            lsm = LSMTree.open_existing(dev, "/db")
            if use_fa:
                fa = Foreactor(device=dev, backend="io_uring", depth=16)
                plugins.register_all(fa)
                get = fa.wrap("lsm_get", plugins.capture_lsm_get)(
                    lambda l, k: l.get(k))
            else:
                get = lambda l, k: l.get(k)
            t0 = time.perf_counter()
            for k, is_get in zip(keys, ops):
                if is_get:
                    get(lsm, int(k))
                else:
                    lsm.put(int(k), b"x" * 64)
            dt = time.perf_counter() - t0
            rows.append((f"lsm_mix_get{int(frac*100)}pct_{label}",
                         dt / n_ops * 1e6, ""))
            lsm.close()
            if use_fa:
                fa.shutdown()
        s, f = rows[-2][1], rows[-1][1]
        rows[-1] = (rows[-1][0], f, f"improvement={100*(1-f/s):.0f}%")
    return rows


def bench_skew(thetas=(0.6, 0.99), n_ops: int = 50) -> List[Row]:
    """Fig. 9(c): less skew -> more cache misses -> more improvement."""
    inner, ref, db_bytes = build_db(n_keys=2000)
    rows: List[Row] = []
    for theta in thetas:
        rng = np.random.default_rng(4)
        keys = zipf_keys(2000, n_ops, theta, rng)
        for use_fa, label in ((False, "sync"), (True, "foreactor")):
            dev = sim(inner, cache_bytes=db_bytes // 5)
            lsm = LSMTree.open_existing(dev, "/db")
            if use_fa:
                fa = Foreactor(device=dev, backend="io_uring", depth=16)
                plugins.register_all(fa)
                get = fa.wrap("lsm_get", plugins.capture_lsm_get)(
                    lambda l, k: l.get(k))
                t = timeit(lambda: [get(lsm, int(k)) for k in keys]) / n_ops
                fa.shutdown()
            else:
                t = timeit(lambda: _gets(lsm, keys)) / n_ops
            rows.append((f"lsm_zipf{theta}_{label}", t * 1e6, ""))
            lsm.close()
        s, f = rows[-2][1], rows[-1][1]
        rows[-1] = (rows[-1][0], f, f"improvement={100*(1-f/s):.0f}%")
    return rows


def run() -> List[Row]:
    return (bench_memory_ratio() + bench_record_size() + bench_clients()
            + bench_op_mix() + bench_skew())
