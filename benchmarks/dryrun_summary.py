"""§Dry-run summary table generator: one row per (arch, shape, mesh) from
reports/dryrun/*.json -> reports/dryrun_summary.md.

    python -m benchmarks.dryrun_summary
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def gib(n):
    return f"{(n or 0) / (1 << 30):.2f}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reports", default="reports/dryrun")
    ap.add_argument("--out", default="reports/dryrun_summary.md")
    args = ap.parse_args()
    rows = []
    for f in sorted(glob.glob(f"{args.reports}/*.json")):
        if "_hc" in os.path.basename(f):
            continue  # hillclimb variants live in §Perf

        r = json.load(open(f))
        h = r["hlo"]
        m = r["memory"]
        coll_sched = ", ".join(f"{k.split('-')[-1]}={gib(v)}G"
                               for k, v in h["collectives"].items() if v)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('profile','')} "
            f"| {gib(m.get('resident_bytes_per_device'))} "
            f"| {gib(m.get('temp_bytes_per_device'))} "
            f"| {h['dot_flops']:.2e} | {r.get('model_flops_per_dev', 0):.2e} "
            f"| {gib(h['collective_bytes'])} | {h['collective_count']} "
            f"| {coll_sched or '—'} | {r['compile_s']:.0f}s |")
    header = [
        "# Dry-run summary (per device; resident = exact sharded inputs, "
        "temp = memory_analysis/devices)",
        "",
        "| arch | shape | mesh | prof | resident GiB | temp GiB | HLO flops "
        "| model flops | coll GiB | #coll | collective schedule | compile |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write("\n".join(header + rows) + "\n")
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
