"""§Roofline generator: three-term roofline per (arch x shape) from the
dry-run reports (single-pod mesh), written to reports/roofline.md + .csv.

    python -m benchmarks.roofline [--reports reports/dryrun] [--mesh 16x16]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.analysis.roofline import HW, roofline
from repro.configs import ARCH_IDS, SHAPES, SKIP_CELLS


def load_reports(report_dir: str, mesh: str):
    out = {}
    for f in glob.glob(f"{report_dir}/*__{mesh}.json"):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reports", default="reports/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--out", default="reports")
    args = ap.parse_args()
    hw = HW()
    reports = load_reports(args.reports, args.mesh)

    md = ["| arch | shape | prof | compute_s | memory_s | collective_s | "
          "dominant | bound_s | MODEL/HLO | note |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    csv = ["arch,shape,profile,compute_s,memory_s,collective_s,dominant,"
           "bound_s,useful_ratio"]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if (arch, shape) in SKIP_CELLS:
                md.append(f"| {arch} | {shape} | — | — | — | — | SKIP | — | — | "
                          f"{SKIP_CELLS[(arch, shape)][:60]} |")
                csv.append(f"{arch},{shape},,,,,SKIP,,")
                continue
            r = reports.get((arch, shape))
            if r is None:
                md.append(f"| {arch} | {shape} | ? | | | | MISSING | | | |")
                csv.append(f"{arch},{shape},,,,,MISSING,,")
                continue
            h = r["hlo"]
            t = roofline(h["dot_flops"], h["dot_bytes"], h["collective_bytes"],
                         hw, r.get("model_flops_per_dev"))
            note = ""
            if t.dominant == "compute" and (t.useful_ratio or 0) < 0.4:
                note = "low useful-FLOP ratio"
            md.append(
                f"| {arch} | {shape} | {r.get('profile','?')} "
                f"| {t.compute_s:.3e} | {t.memory_s:.3e} | {t.collective_s:.3e} "
                f"| **{t.dominant}** | {t.bound_s:.3e} "
                f"| {t.useful_ratio:.2f} | {note} |"
                if t.useful_ratio else
                f"| {arch} | {shape} | {r.get('profile','?')} "
                f"| {t.compute_s:.3e} | {t.memory_s:.3e} | {t.collective_s:.3e} "
                f"| **{t.dominant}** | {t.bound_s:.3e} | — | {note} |")
            csv.append(f"{arch},{shape},{r.get('profile','')},{t.compute_s:.6e},"
                       f"{t.memory_s:.6e},{t.collective_s:.6e},{t.dominant},"
                       f"{t.bound_s:.6e},{t.useful_ratio or ''}")
    os.makedirs(args.out, exist_ok=True)
    with open(f"{args.out}/roofline.md", "w") as f:
        f.write("\n".join(md) + "\n")
    with open(f"{args.out}/roofline.csv", "w") as f:
        f.write("\n".join(csv) + "\n")
    print("\n".join(md))


if __name__ == "__main__":
    main()
