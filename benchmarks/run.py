"""Benchmark harness: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:

* Fig. 6  — du / cp command-line utilities     (bench_utilities)
* Fig. 7 + Table 1 — B+-tree Scan/Load + backend swap (bench_bptree)
* Fig. 8/9 — LSM Get: memory ratio, record size, tails, clients, op mix,
  skew                                          (bench_lsm)
* Fig. 10 — overhead breakdown + framework-plane I/O + the peek-algorithm
  and result-copy microbenchmarks gating the compiled-plan refactor
  (bench_overhead; structured results land in
  benchmarks/results/overhead.json, and ``python -m
  benchmarks.bench_overhead --dry-run --check`` is the CI perf-smoke gate)
* Sharding — multi-device restore/pipeline scaling      (bench_sharding;
  structured results also land in benchmarks/results/sharding.json)
* Adaptive — fixed depth sweep vs the adaptive controller (bench_adaptive;
  structured results also land in benchmarks/results/adaptive.json, and
  ``python -m benchmarks.bench_adaptive --table`` renders the TUNING.md table)
* Serving — multi-tenant shared-backend scheduler vs per-thread isolation
  vs sync (bench_serve; results in benchmarks/results/serve.json, table via
  ``python -m benchmarks.bench_serve --table``)
* Write — undoable write-path speculation: staged checkpoint saves,
  speculative shard writes, write-behind checkpointing vs the serial write
  path (bench_write; results in benchmarks/results/write.json)
* Open loop — fixed-arrival-rate serving sweep to saturation: throughput
  vs p99 and peak in-flight sessions (bench_openloop; results in
  benchmarks/results/openloop.json, table via ``python -m
  benchmarks.bench_openloop --table``, and ``python -m
  benchmarks.bench_openloop --dry-run --check`` is the CI openloop-smoke
  gate)
* Multiget — batched scatter-gather lookups through the futures API: one
  ``lsm_multiget`` plan vs N sequential speculated gets (bench_multiget;
  results in benchmarks/results/multiget.json, table via ``python -m
  benchmarks.bench_multiget --table``, and ``python -m
  benchmarks.bench_multiget --dry-run --check`` is the CI multiget-smoke
  gate)
* Re-mining — drift-to-recovery: LSM compaction mid-serve kills the
  speculation benefit, online re-mining hot-swaps it back (bench_remine;
  results in benchmarks/results/remine.json, table via ``python -m
  benchmarks.bench_remine --table``, and ``python -m
  benchmarks.bench_remine --dry-run --check`` is the CI remine-smoke
  gate)

Roofline tables (§Roofline) are produced separately by
``python -m benchmarks.roofline`` from the dry-run reports.
"""

import sys
import time


def main() -> None:
    from . import (bench_adaptive, bench_bptree, bench_lsm, bench_multiget,
                   bench_openloop, bench_overhead, bench_remine,
                   bench_serve, bench_sharding, bench_utilities, bench_write)
    from .common import fmt

    sections = [
        ("fig6_utilities", bench_utilities.run),
        ("fig7_table1_bptree", bench_bptree.run),
        ("fig8_fig9_lsm", bench_lsm.run),
        ("fig10_overhead_framework", bench_overhead.run),
        ("sharding_multi_device", bench_sharding.run),
        ("adaptive_depth", bench_adaptive.run),
        ("serving_multi_tenant", bench_serve.run),
        ("write_speculation", bench_write.run),
        ("serving_open_loop", bench_openloop.run),
        ("multiget_scatter_gather", bench_multiget.run),
        ("remine_drift_recovery", bench_remine.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in sections:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # pragma: no cover
            print(f"{name},ERROR,{e!r}", flush=True)
            raise
        for line in fmt(rows):
            print(line, flush=True)
        print(f"# section {name} done in {time.time() - t0:.1f}s",
              file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
