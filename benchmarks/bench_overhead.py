"""Paper Fig. 10: latency/overhead factor breakdown of a speculated Get,
plus the framework-plane benchmarks (checkpoint restore, data pipeline)."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import Foreactor, MemDevice
from repro.data import DataConfig, ShardedTokenDataset, TokenBatchLoader, write_synthetic_dataset
from repro.store import plugins

from .bench_lsm import build_db
from .common import Row, sim, timeit
from repro.store.lsm import LSMTree


def bench_get_breakdown(n_ops: int = 60) -> List[Row]:
    """Fig. 10: where time goes inside speculated Gets (engine stats)."""
    inner, ref, db_bytes = build_db(n_keys=2000, record=1024)
    dev = sim(inner, cache_bytes=db_bytes // 10)
    fa = Foreactor(device=dev, backend="io_uring", depth=16)
    plugins.register_all(fa)
    lsm = LSMTree.open_existing(dev, "/db")
    get = fa.wrap("lsm_get", plugins.capture_lsm_get)(lambda l, k: l.get(k))
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2000, n_ops)
    t = timeit(lambda: [get(lsm, int(k)) for k in keys])
    s = fa.total_stats
    per = 1e6 / n_ops
    rows = [
        ("get_total", t / n_ops * 1e6, ""),
        ("get_peek_algorithm", s.peek_seconds * per, "overhead: pre-issuing alg"),
        ("get_wait_completion", s.wait_seconds * per, "io_uring wait"),
        ("get_sync_syscalls", s.sync_seconds * per, "non-speculated syscalls"),
        ("get_result_copy", s.harvest_seconds * per, "overhead: buffer copy"),
        ("get_cancelled", s.cancelled + s.wasted_completions,
         f"overhead: wasted speculative reads over {n_ops} gets"),
    ]
    lsm.close()
    fa.shutdown()
    return rows


def bench_checkpoint(n_mb: int = 24) -> List[Row]:
    """Framework plane: parallel checkpoint save/restore vs serial."""
    rng = np.random.default_rng(0)
    tree = {f"layer{i}": rng.normal(size=(n_mb * 1024 * 1024 // 4 // 8,))
            .astype(np.float32) for i in range(8)}
    rows: List[Row] = []
    for depth, label in ((0, "serial"), (32, "foreactor")):
        inner = MemDevice()
        dev = sim(inner)
        fa = Foreactor(device=dev, backend="io_uring", depth=depth)
        mgr = CheckpointManager(dev, f"/ck_{label}", fa=fa, num_shards=8,
                                chunk_bytes=1 << 20)
        t_save = timeit(lambda: mgr.save(1, tree))
        t_rest = timeit(lambda: mgr.restore(1))
        rows.append((f"ckpt_save_{label}", t_save * 1e6,
                     f"MBps={n_mb / t_save:.0f}"))
        rows.append((f"ckpt_restore_{label}", t_rest * 1e6,
                     f"MBps={n_mb / t_rest:.0f}"))
        fa.shutdown()
    return rows


def bench_pipeline(steps: int = 8) -> List[Row]:
    """Framework plane: batch-load latency with/without speculation."""
    rows: List[Row] = []
    cfg = DataConfig(seq_len=512, batch_size=32, seed=0)
    inner = MemDevice()
    write_synthetic_dataset(inner, "/data", cfg, 4, 128, vocab_size=1000)
    paths = [f"/data/shard_{i:05d}.rio" for i in range(4)]
    for prefetch, label in ((False, "serial"), (True, "foreactor")):
        dev = sim(inner)
        fa = Foreactor(device=dev, backend="io_uring", depth=32)
        loader = TokenBatchLoader(ShardedTokenDataset(dev, paths), cfg,
                                  fa=fa, prefetch=prefetch)
        t0 = time.perf_counter()
        for s in range(steps):
            loader.load(0, s)
        dt = (time.perf_counter() - t0) / steps
        rows.append((f"data_batch_{label}", dt * 1e6,
                     f"tokens_per_s={cfg.batch_size * cfg.seq_len / dt:.0f}"))
        loader.close()
        fa.shutdown()
    return rows


def run() -> List[Row]:
    return bench_get_breakdown() + bench_checkpoint() + bench_pipeline()
