"""Paper Fig. 10: latency/overhead factor breakdown of a speculated Get,
the framework-plane benchmarks (checkpoint restore, data pipeline), and the
engine-overhead microbenchmarks that gate the compiled-plan refactor:

* **Peek algorithm** — Algorithm 1's interpretation cost per intercepted
  syscall, isolated on the sync backend (no workers, no simulated latency,
  no GIL contention: ``peek_seconds`` is the pure walk + request-build +
  submit-bookkeeping cost).  Three authoring styles: the lsm_get plugin
  graph (branch + weak loop), a mined-style all-weak 24-node chain, and the
  strong-edge extent loop.  The committed pre-refactor baseline
  (:data:`PRE_REFACTOR_BASELINE`, measured at the object-walker commit with
  this exact harness) is what the acceptance gate compares against.
* **Result copy** — end-to-end result delivery through the I/O plane with
  the registered buffer pool on vs off: N preads submitted in one batch,
  drained, materialized.  Pool off is the classic allocate-per-request
  path; pool on leases registered buffers (``pread_into``) and pays one
  bounded memcpy at ``take_result``.
* **Completion primitive** — per-IORequest completion constant on the
  pooled stripe table (:mod:`repro.core.completion`) vs the committed
  per-request ``threading.Event`` baseline
  (:data:`EVENT_COMPLETION_BASELINE`, measured at commit cb5d139): the
  full claim/finish/harvest lifecycle and the cancel/poll teardown path.

``python -m benchmarks.bench_overhead`` writes
``benchmarks/results/overhead.json`` (rendered into docs/BENCHMARKS.md by
``tools/bench_report.py``).  ``--dry-run`` runs only the fast
microbenchmarks; with ``--check`` it compares the fresh measurement against
the committed results and exits nonzero on a peek-overhead regression
(soft threshold — CI variance is real; the perf-smoke job adds the hard
timeout).
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import Foreactor, GraphBuilder, MemDevice, QueuePairBackend, Sys, io
from repro.core.patterns import build_pread_extents_graph
from repro.core.syscalls import IORequest
from repro.store import plugins
from repro.store.lsm import LSMTree

from .common import Row, sim, timeit

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "overhead.json")

#: Peek-algorithm overhead of the pre-refactor object-graph walker,
#: measured at commit 10329d0 (the last commit before the compiled-plan
#: refactor) with exactly the ``peek_*`` harness below (sync backend,
#: MemDevice, depth 16, best of 5).  Committed so the acceptance gate —
#: plan interpreter >= 2x cheaper per speculated Get — and the CI
#: perf-smoke job always have a fixed denominator.
PRE_REFACTOR_BASELINE: Dict[str, float] = {
    "lsm_get_us_per_get": 237.88,
    "lsm_get_us_per_intercept": 43.37,
    "weak_chain_us_per_intercept": 31.10,
    "extent_loop_us_per_intercept": 18.24,
}

#: Per-IORequest completion cost of the pre-pool implementation (one
#: ``threading.Event`` + one claim ``threading.Lock`` allocated per
#: request), measured at commit cb5d139 with exactly the
#: ``measure_completion`` harness below (best of 5).  The pooled-completion
#: acceptance gate: the stripe-table primitive must keep the per-record
#: constant below these.
EVENT_COMPLETION_BASELINE: Dict[str, float] = {
    "lifecycle_us_per_req": 12.72,  # construct + claim + finish + wait_result
    "cancel_us_per_req": 7.89,      # construct + cancel + poll
}


# ---------------------------------------------------------------------------
# Fig. 10 breakdown + framework plane (simulated device, end to end)
# ---------------------------------------------------------------------------
def bench_get_breakdown(n_ops: int = 60) -> List[Row]:
    """Fig. 10: where time goes inside speculated Gets (engine stats)."""
    from .bench_lsm import build_db

    inner, ref, db_bytes = build_db(n_keys=2000, record=1024)
    dev = sim(inner, cache_bytes=db_bytes // 10)
    fa = Foreactor(device=dev, backend="io_uring", depth=16)
    plugins.register_all(fa)
    lsm = LSMTree.open_existing(dev, "/db")
    get = fa.wrap("lsm_get", plugins.capture_lsm_get)(lambda l, k: l.get(k))
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2000, n_ops)
    t = timeit(lambda: [get(lsm, int(k)) for k in keys])
    s = fa.total_stats
    per = 1e6 / n_ops
    rows = [
        ("get_total", t / n_ops * 1e6, ""),
        ("get_peek_algorithm", s.peek_seconds * per, "overhead: pre-issuing alg"),
        ("get_wait_completion", s.wait_seconds * per, "io_uring wait"),
        ("get_sync_syscalls", s.sync_seconds * per, "non-speculated syscalls"),
        ("get_result_copy", s.harvest_seconds * per, "overhead: buffer copy"),
        ("get_cancelled", s.cancelled + s.wasted_completions,
         f"overhead: wasted speculative reads over {n_ops} gets"),
    ]
    lsm.close()
    fa.shutdown()
    return rows


def bench_checkpoint(n_mb: int = 24) -> List[Row]:
    """Framework plane: parallel checkpoint save/restore vs serial."""
    from repro.checkpoint import CheckpointManager

    rng = np.random.default_rng(0)
    tree = {f"layer{i}": rng.normal(size=(n_mb * 1024 * 1024 // 4 // 8,))
            .astype(np.float32) for i in range(8)}
    rows: List[Row] = []
    for depth, label in ((0, "serial"), (32, "foreactor")):
        inner = MemDevice()
        dev = sim(inner)
        fa = Foreactor(device=dev, backend="io_uring", depth=depth)
        mgr = CheckpointManager(dev, f"/ck_{label}", fa=fa, num_shards=8,
                                chunk_bytes=1 << 20)
        t_save = timeit(lambda: mgr.save(1, tree))
        t_rest = timeit(lambda: mgr.restore(1))
        rows.append((f"ckpt_save_{label}", t_save * 1e6,
                     f"MBps={n_mb / t_save:.0f}"))
        rows.append((f"ckpt_restore_{label}", t_rest * 1e6,
                     f"MBps={n_mb / t_rest:.0f}"))
        fa.shutdown()
    return rows


def bench_pipeline(steps: int = 8) -> List[Row]:
    """Framework plane: batch-load latency with/without speculation."""
    from repro.data import (DataConfig, ShardedTokenDataset, TokenBatchLoader,
                            write_synthetic_dataset)

    rows: List[Row] = []
    cfg = DataConfig(seq_len=512, batch_size=32, seed=0)
    inner = MemDevice()
    write_synthetic_dataset(inner, "/data", cfg, 4, 128, vocab_size=1000)
    paths = [f"/data/shard_{i:05d}.rio" for i in range(4)]
    for prefetch, label in ((False, "serial"), (True, "foreactor")):
        dev = sim(inner)
        fa = Foreactor(device=dev, backend="io_uring", depth=32)
        loader = TokenBatchLoader(ShardedTokenDataset(dev, paths), cfg,
                                  fa=fa, prefetch=prefetch)
        t0 = time.perf_counter()
        for s in range(steps):
            loader.load(0, s)
        dt = (time.perf_counter() - t0) / steps
        rows.append((f"data_batch_{label}", dt * 1e6,
                     f"tokens_per_s={cfg.batch_size * cfg.seq_len / dt:.0f}"))
        loader.close()
        fa.shutdown()
    return rows


# ---------------------------------------------------------------------------
# Peek-algorithm microbenchmarks (sync backend: pure Algorithm-1 cost)
# ---------------------------------------------------------------------------
def peek_lsm_get(n_ops: int = 400, depth: int = 16) -> Dict[str, float]:
    """The paper's Get workload: branchy plugin graph, weak early-exit loop."""
    from .bench_lsm import build_db

    inner, _ref, _db = build_db(n_keys=2000, record=1024)
    fa = Foreactor(device=inner, backend="sync", depth=depth)
    plugins.register_all(fa)
    lsm = LSMTree.open_existing(inner, "/db")
    get = fa.wrap("lsm_get", plugins.capture_lsm_get)(lambda l, k: l.get(k))
    keys = np.random.default_rng(0).integers(0, 2000, n_ops)
    for k in keys[:20]:
        get(lsm, int(k))  # warmup: build + compile cached, pool warmed
    s0 = fa.total_stats.peek_seconds
    i0 = fa.total_stats.intercepted
    for k in keys:
        get(lsm, int(k))
    s = fa.total_stats
    out = {
        "lsm_get_us_per_get": (s.peek_seconds - s0) / n_ops * 1e6,
        "lsm_get_us_per_intercept":
            (s.peek_seconds - s0) / (s.intercepted - i0) * 1e6,
    }
    lsm.close()
    fa.shutdown()
    return out


def _build_chain(name: str, n_steps: int, size: int):
    b = GraphBuilder(name)
    prev = None
    for i in range(n_steps):
        b.AddSyscallNode(f"s{i}", Sys.PREAD,
                         lambda ctx, ep, i=i: ((ctx["fd"], size, 0), False))
        if prev is not None:
            b.SyscallSetNext(prev, f"s{i}", weak=True)
        prev = f"s{i}"
    b.SyscallSetNext(prev, None, weak=True)
    return b.Build()


def peek_weak_chain(n_calls: int = 150, n_steps: int = 24, size: int = 256,
                    depth: int = 16) -> Dict[str, float]:
    """Mined-style all-weak chain: the authoring style that defeated the
    old walker's sliding window (it re-walked the whole window per call)."""
    dev = MemDevice()
    fd = dev.open("/w/f", "w")
    dev.pwrite(fd, bytes(size), 0)
    dev.close(fd)
    fa = Foreactor(device=dev, backend="sync", depth=depth)
    fa.register("chain", lambda: _build_chain("chain", n_steps, size))
    rfd = dev.open("/w/f", "r")

    @fa.wrap("chain", lambda: {"fd": rfd})
    def prog():
        for _ in range(n_steps):
            io.pread(dev, rfd, size, 0)

    for _ in range(10):
        prog()
    s0, i0 = fa.total_stats.peek_seconds, fa.total_stats.intercepted
    for _ in range(n_calls):
        prog()
    s = fa.total_stats
    out = {"weak_chain_us_per_intercept":
           (s.peek_seconds - s0) / (s.intercepted - i0) * 1e6}
    fa.shutdown()
    return out


def peek_extent_loop(n_calls: int = 150, n_extents: int = 64,
                     size: int = 256, depth: int = 16) -> Dict[str, float]:
    """Strong-edge pread loop (restore shape): already amortized O(1) under
    the sliding window; measures the interpreter's constant factor."""
    dev = MemDevice()
    fd = dev.open("/e/data", "w")
    dev.pwrite(fd, bytes(n_extents * size), 0)
    dev.close(fd)
    fa = Foreactor(device=dev, backend="sync", depth=depth)
    fa.register("extents", lambda: build_pread_extents_graph("extents"))
    rfd = dev.open("/e/data", "r")
    extents = [(rfd, size, i * size) for i in range(n_extents)]

    @fa.wrap("extents", lambda: {"extents": extents})
    def prog():
        for (f, s_, off) in extents:
            io.pread(dev, f, s_, off)

    for _ in range(10):
        prog()
    s0, i0 = fa.total_stats.peek_seconds, fa.total_stats.intercepted
    for _ in range(n_calls):
        prog()
    s = fa.total_stats
    out = {"extent_loop_us_per_intercept":
           (s.peek_seconds - s0) / (s.intercepted - i0) * 1e6}
    fa.shutdown()
    return out


def measure_peek(repeats: int = 5) -> Dict[str, float]:
    """Best-of-N for each workload (min sheds CI scheduler noise)."""
    out: Dict[str, float] = {}
    for fn in (peek_lsm_get, peek_weak_chain, peek_extent_loop):
        runs = [fn() for _ in range(repeats)]
        best = min(runs, key=lambda r: next(iter(r.values())))
        out.update(best)
    return out


# ---------------------------------------------------------------------------
# Result-copy microbenchmark (registered buffer pool on vs off)
# ---------------------------------------------------------------------------
def measure_result_copy(n: int = 512, size: int = 64 * 1024,
                        workers: int = 4, repeats: int = 5) -> Dict:
    """End-to-end result delivery through the plane: submit N preads in one
    batch, drain, materialize every result.  Pool off allocates a fresh
    result per request (bytearray slice + bytes pair on MemDevice); pool on
    fills recycled registered buffers and pays one memcpy at take."""
    out: Dict = {"config": {"n": n, "size_bytes": size, "workers": workers,
                            "repeats": repeats}}
    for pool_on in (False, True):
        dev = MemDevice()
        fd = dev.open("/big", "w")
        dev.pwrite(fd, b"\xab" * (n * size), 0)
        dev.close(fd)
        be = QueuePairBackend(dev, workers=workers)
        if not pool_on:
            be.pool = None
        rfd = dev.open("/big", "r")
        best = float("inf")
        for _ in range(repeats):
            reqs = [IORequest(sc=Sys.PREAD, args=(rfd, size, i * size))
                    for i in range(n)]
            t0 = time.perf_counter()
            be.submit(reqs)
            be.drain()
            delivered = [r.take_result() for r in reqs]
            best = min(best, time.perf_counter() - t0)
            assert all(len(d) == size for d in delivered)
            for r in reqs:
                if r.lease is not None:
                    r.lease.release()
        key = "pool_on" if pool_on else "pool_off"
        out[key] = {"us_per_op": best / n * 1e6}
        if pool_on and be.pool is not None:
            out[key].update({"hit_rate": round(be.pool.hit_rate, 3),
                             "registered_mb":
                                 be.pool.registered_bytes / (1 << 20)})
        be.shutdown()
    out["speedup"] = out["pool_off"]["us_per_op"] / out["pool_on"]["us_per_op"]
    return out


# ---------------------------------------------------------------------------
# Completion-primitive microbenchmark (pooled stripes vs per-request Event)
# ---------------------------------------------------------------------------
def measure_completion(n: int = 20000, repeats: int = 5) -> Dict[str, float]:
    """Per-IORequest completion constant on the pooled stripe table, with
    the same loops the committed :data:`EVENT_COMPLETION_BASELINE` was
    measured with on the per-request-Event implementation:

    * **lifecycle** — construct, claim (worker pickup), finish, harvest via
      ``wait_result`` (the already-completed fast path every pre-issued-
      and-demanded request takes);
    * **cancel** — construct, cancel, poll ``is_done`` (the early-exit
      teardown path every wasted speculative request takes).

    At open-loop scale both run millions of times; the per-record constant
    is what the pooled primitive exists to shrink."""
    best_life = best_cancel = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _i in range(n):
            r = IORequest(sc=Sys.PREAD, args=(0, 16, 0))
            r.claim()
            r.finish(b"x")
            r.wait_result()
        best_life = min(best_life, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for _i in range(n):
            r = IORequest(sc=Sys.PREAD, args=(0, 16, 0))
            r.cancel()
            r.is_done()
        best_cancel = min(best_cancel, time.perf_counter() - t0)
    return {"lifecycle_us_per_req": best_life / n * 1e6,
            "cancel_us_per_req": best_cancel / n * 1e6}


# ---------------------------------------------------------------------------
# Structured results + the CI gate
# ---------------------------------------------------------------------------
def collect(dry_run: bool = False) -> Dict:
    peek = measure_peek(repeats=3 if dry_run else 5)
    copy = measure_result_copy(n=128 if dry_run else 512,
                               repeats=3 if dry_run else 5)
    comp = measure_completion(n=5000 if dry_run else 20000,
                              repeats=3 if dry_run else 5)
    base = PRE_REFACTOR_BASELINE
    result = {
        "config": {
            "methodology": "sync-backend isolated peek (pure Algorithm-1 "
                           "cost), MemDevice, depth 16, best-of-N; "
                           "result delivery via QueuePairBackend",
            "baseline_commit": "10329d0 (pre-refactor object walker)",
            "dry_run": dry_run,
        },
        "peek": {
            "baseline": dict(base),
            "plan": peek,
            "speedup_lsm_get_per_get":
                base["lsm_get_us_per_get"] / peek["lsm_get_us_per_get"],
            "speedup_weak_chain":
                base["weak_chain_us_per_intercept"]
                / peek["weak_chain_us_per_intercept"],
            "speedup_extent_loop":
                base["extent_loop_us_per_intercept"]
                / peek["extent_loop_us_per_intercept"],
        },
        "result_copy": copy,
        "completion": {
            "baseline": dict(EVENT_COMPLETION_BASELINE),
            "pooled": comp,
            "speedup_lifecycle":
                EVENT_COMPLETION_BASELINE["lifecycle_us_per_req"]
                / comp["lifecycle_us_per_req"],
            "speedup_cancel":
                EVENT_COMPLETION_BASELINE["cancel_us_per_req"]
                / comp["cancel_us_per_req"],
        },
    }
    return result


def check(fresh: Dict, committed: Dict) -> List[str]:
    """Perf-smoke gate: the fresh dry-run measurement must not regress
    against the committed results.  Soft thresholds (CI containers are
    noisy; the job's hard timeout catches pathological hangs):

    * peek per speculated Get must stay >= 1.5x under the pre-refactor
      baseline (the acceptance criterion was 2x at measurement time);
    * each peek workload must stay within 3x of its committed value;
    * pooled result delivery must not be slower than unpooled.
    """
    errs = []
    base = committed["peek"]["baseline"]
    plan = committed["peek"]["plan"]
    got = fresh["peek"]["plan"]
    if got["lsm_get_us_per_get"] > base["lsm_get_us_per_get"] / 1.5:
        errs.append(
            f"peek regressed: {got['lsm_get_us_per_get']:.1f} us/get vs "
            f"pre-refactor baseline {base['lsm_get_us_per_get']:.1f} "
            "(must stay >= 1.5x under it)")
    for key in got:
        if key in plan and got[key] > plan[key] * 3:
            errs.append(f"peek {key}: {got[key]:.1f} us vs committed "
                        f"{plan[key]:.1f} us (>3x slack)")
    if fresh["result_copy"]["speedup"] < 1.0:
        errs.append(
            f"buffer pool no longer wins result delivery: speedup "
            f"{fresh['result_copy']['speedup']:.2f}x < 1.0x")
    comp = fresh.get("completion")
    if comp is not None:
        base_c = comp["baseline"]
        got_c = comp["pooled"]
        for key in ("lifecycle_us_per_req", "cancel_us_per_req"):
            if got_c[key] > base_c[key]:
                errs.append(
                    f"pooled completion regressed past the per-request-"
                    f"Event baseline: {key} {got_c[key]:.2f} us vs "
                    f"{base_c[key]:.2f} us")
    return errs


def run() -> List[Row]:
    """run.py section: Fig. 10 + framework plane + overhead microbenches
    (also refreshes benchmarks/results/overhead.json)."""
    result = collect()
    with open(RESULTS_PATH, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
    rows = bench_get_breakdown() + bench_checkpoint() + bench_pipeline()
    p = result["peek"]
    rows += [
        ("peek_lsm_get_plan", p["plan"]["lsm_get_us_per_get"],
         f"vs baseline {p['baseline']['lsm_get_us_per_get']:.1f}us: "
         f"{p['speedup_lsm_get_per_get']:.2f}x"),
        ("peek_weak_chain_plan", p["plan"]["weak_chain_us_per_intercept"],
         f"{p['speedup_weak_chain']:.2f}x vs walker"),
        ("peek_extent_loop_plan", p["plan"]["extent_loop_us_per_intercept"],
         f"{p['speedup_extent_loop']:.2f}x vs walker"),
        ("result_copy_pool_off", result["result_copy"]["pool_off"]["us_per_op"],
         "alloc-per-request"),
        ("result_copy_pool_on", result["result_copy"]["pool_on"]["us_per_op"],
         f"registered buffers, {result['result_copy']['speedup']:.2f}x"),
        ("completion_lifecycle_pooled",
         result["completion"]["pooled"]["lifecycle_us_per_req"],
         f"{result['completion']['speedup_lifecycle']:.2f}x vs "
         "per-request Event"),
        ("completion_cancel_pooled",
         result["completion"]["pooled"]["cancel_us_per_req"],
         f"{result['completion']['speedup_cancel']:.2f}x vs "
         "per-request Event"),
    ]
    return rows


def main(argv: List[str]) -> int:
    dry = "--dry-run" in argv
    fresh = collect(dry_run=dry)
    if "--check" in argv:
        with open(RESULTS_PATH) as f:
            committed = json.load(f)
        errs = check(fresh, committed)
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        print(json.dumps(fresh["peek"]["plan"], indent=2))
        print("perf-smoke:", "FAIL" if errs else "ok")
        return 1 if errs else 0
    if not dry:
        with open(RESULTS_PATH, "w") as f:
            json.dump(fresh, f, indent=2, sort_keys=True)
        print(f"wrote {RESULTS_PATH}")
    print(json.dumps(fresh, indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
