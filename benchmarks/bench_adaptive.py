"""Adaptive speculation depth vs fixed-depth sweeps (docs/TUNING.md).

Three workloads with opposite depth preferences, all on the simulated
device so the effect is deterministic in CI:

* **scan_deep** — one long pure pread loop (192 extents).  Deeper is
  better until the device's channel parallelism saturates; depth 1 leaves
  the device almost idle.
* **search_early_exit** — an LSM-get-shaped weak-edge read chain over 64
  candidates that exits at the third read, repeated per run.  Depth beyond
  the exit point only buys cancellation + drain time (paper Fig. 10), so
  the *deepest* fixed depth is the worst config here.
* **stat_batch** — a du-shaped fstatat loop over 24 paths, invoked
  repeatedly (short sessions; convergence must happen across calls).

Each workload is swept over ``FIXED_DEPTHS`` and the adaptive controller
(``depth="adaptive"``).  The controller is warmed up with a few
invocations (it persists per graph on the ``Foreactor``), then timed at
steady state — exactly how a long-running service would experience it.

Headline numbers (written to ``benchmarks/results/adaptive.json``):
``summary.<workload>.adaptive_vs_best`` (target: <= 1.10, within 10% of
the best fixed depth) and ``summary.<workload>.worst_vs_adaptive``
(target: >= 1.25, beating the worst fixed depth by 25%+).

``python -m benchmarks.bench_adaptive --table`` renders the result JSON
as the markdown table embedded in docs/TUNING.md.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List

from repro.core import (DeviceProfile, Foreactor, MemDevice, SimulatedDevice,
                        io)
from repro.core.patterns import build_pread_extents_graph, build_stat_list_graph

from .common import RESULTS_DIR, Row, timeit_min, write_results

FIXED_DEPTHS = (1, 4, 16, 64)
CHANNELS = 16

#: ms-scale per-op latency: far above CI sleep granularity, so the ordering
#: between depth configs is stable run to run
ADAPTIVE_PROFILE = DeviceProfile(channels=CHANNELS, base_latency=1.2e-3,
                                 metadata_latency=1.0e-3, per_byte=2.0e-10,
                                 crossing_cost=4e-6)


def _make_dev(nfiles: int, size: int = 512):
    inner = MemDevice()
    paths = []
    for i in range(nfiles):
        p = f"/bench/f{i}"
        fd = inner.open(p, "w")
        inner.pwrite(fd, bytes([i % 251]) * size, 0)
        inner.close(fd)
        paths.append(p)
    return SimulatedDevice(inner, ADAPTIVE_PROFILE), paths


def _fa(dev, depth):
    return Foreactor(device=dev, backend="io_uring", depth=depth,
                     workers=CHANNELS, depth_range=(1, 64))


def _run_config(make_workload, depth, warmup: int, repeats: int):
    """Time one (workload, depth-config) pair; returns (seconds, info)."""
    fa, run_once, graph_name = make_workload(depth)
    try:
        t = timeit_min(run_once, repeats=repeats, warmup=warmup)
        info = {}
        if depth == "adaptive":
            info = fa.controller(graph_name).snapshot()
        return t, info
    finally:
        fa.shutdown()


# -- workloads ----------------------------------------------------------------
def scan_deep(depth):
    dev, paths = _make_dev(192)
    fa = _fa(dev, depth)
    fa.register("scan", lambda: build_pread_extents_graph("scan"))
    extents = []
    for p in paths:
        fd = dev.open(p, "r")
        extents.append((fd, 512, 0))

    @fa.wrap("scan", lambda: {"extents": extents})
    def scan():
        total = 0
        for fd, n, off in extents:
            total += len(io.pread(dev, fd, n, off))
        return total

    return fa, scan, "scan"


def search_early_exit(depth, gets_per_run: int = 10, exit_at: int = 2):
    dev, paths = _make_dev(64)
    fa = _fa(dev, depth)
    fa.register("search", lambda: build_pread_extents_graph("search", weak=True))
    extents = []
    for p in paths:
        fd = dev.open(p, "r")
        extents.append((fd, 512, 0))

    @fa.wrap("search", lambda: {"extents": extents})
    def one_get():
        for i, (fd, n, off) in enumerate(extents):
            data = io.pread(dev, fd, n, off)
            if i == exit_at:
                return data
        return None

    def run():
        for _ in range(gets_per_run):
            one_get()

    return fa, run, "search"


def stat_batch(depth, calls_per_run: int = 4):
    dev, paths = _make_dev(24)
    fa = _fa(dev, depth)
    fa.register("stats", build_stat_list_graph)

    @fa.wrap("stats", lambda: {"paths": paths})
    def one_batch():
        return sum(io.fstatat(dev, p).st_size for p in paths)

    def run():
        for _ in range(calls_per_run):
            one_batch()

    return fa, run, "stats"


WORKLOADS = [
    ("scan_deep", scan_deep, 1, 2),
    ("search_early_exit", search_early_exit, 2, 2),
    ("stat_batch", stat_batch, 2, 2),
]
#: extra steady-state warmup for the adaptive controller (it has to learn)
ADAPTIVE_WARMUP = {"scan_deep": 2, "search_early_exit": 3, "stat_batch": 3}


def bench() -> Dict[str, Dict]:
    out: Dict[str, Dict] = {"config": {
        "fixed_depths": list(FIXED_DEPTHS), "channels": CHANNELS,
    }}
    summary: Dict[str, Dict] = {}
    for wname, make, warmup, repeats in WORKLOADS:
        cells: Dict[str, Dict] = {}
        for d in FIXED_DEPTHS:
            t, _ = _run_config(make, d, warmup, repeats)
            cells[str(d)] = {"seconds": t}
        t, info = _run_config(make, "adaptive", ADAPTIVE_WARMUP[wname], repeats)
        cells["adaptive"] = {"seconds": t, "controller": info}
        out[wname] = cells
        fixed = {d: cells[str(d)]["seconds"] for d in FIXED_DEPTHS}
        best_d = min(fixed, key=fixed.get)
        worst_d = max(fixed, key=fixed.get)
        summary[wname] = {
            "best_fixed_depth": best_d,
            "worst_fixed_depth": worst_d,
            "adaptive_vs_best": t / fixed[best_d],
            "worst_vs_adaptive": fixed[worst_d] / t,
            "within_10pct_of_best": t <= 1.10 * fixed[best_d],
            "beats_worst_by_25pct": fixed[worst_d] >= 1.25 * t,
        }
    out["summary"] = summary
    return out


def run() -> List[Row]:
    out = bench()
    path = write_results("adaptive", out)
    rows: List[Row] = []
    for wname, _make, _w, _r in WORKLOADS:
        for key, cell in out[wname].items():
            if key == "config":
                continue
            rows.append((f"adaptive_{wname}_depth{key}",
                         cell["seconds"] * 1e6, ""))
        s = out["summary"][wname]
        rows.append((
            f"adaptive_{wname}_summary", 0.0,
            f"vs_best=x{s['adaptive_vs_best']:.2f} "
            f"vs_worst=x{s['worst_vs_adaptive']:.2f}",
        ))
    rows.append(("adaptive_results_json", 0.0, path))
    return rows


def render_table(path: str = None) -> str:
    """The markdown table embedded in docs/TUNING.md, generated from the
    benchmark's JSON results."""
    path = path or os.path.join(RESULTS_DIR, "adaptive.json")
    with open(path) as f:
        data = json.load(f)
    depths = data["config"]["fixed_depths"]
    header = ("| workload | " + " | ".join(f"depth {d}" for d in depths)
              + " | adaptive | adaptive vs best | worst vs adaptive |")
    sep = "|" + "---|" * (len(depths) + 4)
    lines = [header, sep]
    for wname, _make, _w, _r in WORKLOADS:
        cells = data[wname]
        s = data["summary"][wname]
        ms = [f"{cells[str(d)]['seconds'] * 1e3:.1f} ms" for d in depths]
        lines.append(
            f"| {wname} | " + " | ".join(ms)
            + f" | {cells['adaptive']['seconds'] * 1e3:.1f} ms"
            + f" | x{s['adaptive_vs_best']:.2f}"
            + f" | x{s['worst_vs_adaptive']:.2f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    if "--table" in sys.argv:
        print(render_table())
    else:
        for line in run():
            print(",".join(str(x) for x in line))
