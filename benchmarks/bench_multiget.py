"""Scatter-gather multiget: one generated plan vs N speculated point gets.

``LSMTree.multi_get`` fans a whole batch of point lookups into a single
``lsm_multiget`` foreaction plan: every key's candidate-block chain is
flattened round-robin into one pread loop, issued through the futures API
(``io.pread_async``), and harvested at one barrier with per-key early
exit.  The baseline is the strongest *per-key* configuration this repo
has — N sequential ``lsm_get`` activations, each speculating its own
candidate chain on the same io_uring queue-pair backend — so the measured
gap is purely cross-key parallelism: one session's worth of submission
batching and device-channel occupancy instead of N sessions paying one
blocking demand round each.

``python -m benchmarks.bench_multiget`` writes
``benchmarks/results/multiget.json`` (rendered into docs/BENCHMARKS.md by
``tools/bench_report.py``); ``--table`` renders the batch-size sweep;
``--dry-run --check`` is the CI multiget-smoke gate: the fresh dry run
must produce oracle-identical values with a working speedup, and the
committed full-size results must keep the acceptance number —
batch-16 multiget >= 2x faster than 16 sequential speculated gets.
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.core import Foreactor
from repro.store import plugins
from repro.store.lsm import LSMTree

from .bench_lsm import build_db
from .common import sim, timeit_min, write_results

BATCH_SWEEP = [2, 4, 8, 16, 32]
N_KEYS = 2000
L0_TABLES = 6  # ~6-candidate search chains per key
BATCHES_PER_CELL = 4  # distinct key batches timed per sweep cell
SEED = 11

#: the acceptance number, gated in --check against the committed results
MIN_SPEEDUP_AT_16 = 2.0


def _draw_batches(rng, n_keys: int, batch: int, count: int) -> List[List[int]]:
    return [[int(k) for k in rng.choice(n_keys, size=batch, replace=False)]
            for _ in range(count)]


def collect(dry_run: bool = False) -> Dict:
    sweep_batches = [4, 16] if dry_run else BATCH_SWEEP
    n_keys = 600 if dry_run else N_KEYS
    repeats = 2 if dry_run else 3
    inner, ref, _db_bytes = build_db(n_keys=n_keys, record=256,
                                     l0_tables=L0_TABLES)
    rng = np.random.default_rng(SEED)

    dev = sim(inner)  # BENCH_PROFILE: 16 channels, no page cache
    fa = Foreactor(device=dev, backend="io_uring", depth=32, workers=16)
    plugins.register_all(fa, precompile=True)
    lsm = LSMTree.open_existing(dev, "/db")
    get = fa.wrap("lsm_get", plugins.capture_lsm_get)(lambda l, k: l.get(k))
    mget = fa.wrap("lsm_multiget", plugins.capture_lsm_multiget)(
        lambda l, ks: l.multi_get(ks))

    cells: List[Dict] = []
    for batch in sweep_batches:
        batches = _draw_batches(rng, n_keys, batch, BATCHES_PER_CELL)
        for keys in batches:  # correctness before timing: oracle-identical
            want = [ref[k] for k in keys]
            assert mget(lsm, keys) == want
            assert [get(lsm, k) for k in keys] == want

        def run_seq(bs=batches):
            for keys in bs:
                for k in keys:
                    get(lsm, k)

        def run_mget(bs=batches):
            for keys in bs:
                mget(lsm, keys)

        t_seq = timeit_min(run_seq, repeats=repeats) / len(batches)
        t_mget = timeit_min(run_mget, repeats=repeats) / len(batches)
        cells.append({
            "batch": batch,
            "sequential_ms": t_seq * 1e3,
            "multiget_ms": t_mget * 1e3,
            "speedup": t_seq / t_mget,
        })
        print(f"# multiget batch={batch} seq={t_seq*1e3:.2f}ms "
              f"mget={t_mget*1e3:.2f}ms speedup={t_seq/t_mget:.2f}x",
              file=sys.stderr, flush=True)
    lsm.close()
    fa.shutdown()

    by_batch = {c["batch"]: c for c in cells}
    return {
        "config": {
            "batch_sweep": sweep_batches,
            "n_keys": n_keys,
            "l0_tables": L0_TABLES,
            "batches_per_cell": BATCHES_PER_CELL,
            "seed": SEED,
            "dry_run": dry_run,
            "methodology": "io_uring queue pair, depth 32, BENCH_PROFILE "
                           "simulated device; baseline is N sequential "
                           "speculated lsm_get activations over the same "
                           "keys; best-of-N wall time per cell",
        },
        "sweep": cells,
        "summary": {
            "speedup_at_16": by_batch.get(16, {}).get("speedup"),
            "max_speedup": max(c["speedup"] for c in cells),
            "min_speedup": min(c["speedup"] for c in cells),
        },
    }


def check(fresh: Dict, committed: Optional[Dict]) -> List[str]:
    """CI smoke gate.  The fresh (dry-run-sized) sweep proves the whole
    futures/multiget path end to end (collect() itself asserts values are
    oracle-identical) and that batching is at least directionally faster at
    batch 16.  The committed full-size results must keep the acceptance
    number: >= 2x over sequential speculated gets at batch 16."""
    errs: List[str] = []
    for c in fresh["sweep"]:
        if c["speedup"] <= 0:
            errs.append(f"batch {c['batch']}: nonsensical speedup "
                        f"{c['speedup']}")
    s16 = fresh["summary"].get("speedup_at_16")
    if s16 is None:
        errs.append("fresh sweep has no batch-16 cell")
    elif s16 < 1.2:
        errs.append(f"fresh batch-16 multiget barely beats sequential "
                    f"({s16:.2f}x < 1.2x)")
    if committed is not None:
        cs16 = committed["summary"].get("speedup_at_16")
        if cs16 is None or cs16 < MIN_SPEEDUP_AT_16:
            errs.append(f"committed batch-16 speedup fell below "
                        f"{MIN_SPEEDUP_AT_16}x (got {cs16})")
        if committed["summary"].get("min_speedup", 0) <= 1.0:
            errs.append("committed sweep has a cell where multiget LOSES "
                        "to sequential gets")
    return errs


def render_table(d: Dict) -> str:
    lines = ["| batch | sequential (ms) | multiget (ms) | speedup |",
             "|---|---|---|---|"]
    for c in d["sweep"]:
        lines.append(f"| {c['batch']} | {c['sequential_ms']:.2f} "
                     f"| {c['multiget_ms']:.2f} | {c['speedup']:.2f}x |")
    return "\n".join(lines)


def run():
    """run.py section (also refreshes benchmarks/results/multiget.json)."""
    d = collect()
    write_results("multiget", d)
    s = d["summary"]
    c16 = next(c for c in d["sweep"] if c["batch"] == 16)
    return [
        ("multiget_batch16", c16["multiget_ms"] * 1e3,
         f"speedup={s['speedup_at_16']:.2f}x"),
        ("multiget_batch16_sequential_baseline", c16["sequential_ms"] * 1e3,
         ""),
    ]


def main(argv: List[str]) -> int:
    import os

    dry = "--dry-run" in argv
    results_path = os.path.join(os.path.dirname(__file__), "results",
                                "multiget.json")
    if "--table" in argv:
        with open(results_path) as f:
            print(render_table(json.load(f)))
        return 0
    fresh = collect(dry_run=dry)
    if "--check" in argv:
        committed = None
        if os.path.exists(results_path):
            with open(results_path) as f:
                committed = json.load(f)
        errs = check(fresh, committed)
        for e in errs:
            print(f"FAIL: {e}", file=sys.stderr)
        print(json.dumps(fresh["summary"], indent=2, sort_keys=True))
        print("multiget-smoke:", "FAIL" if errs else "ok")
        return 1 if errs else 0
    if not dry:
        write_results("multiget", fresh)
        print("wrote benchmarks/results/multiget.json")
    print(json.dumps(fresh["summary"], indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
