"""Shared benchmark fixtures: devices, datasets, timing helpers.

All benchmarks run against :class:`SimulatedDevice` with the remote-tier
profile (DESIGN.md §2.3) so the storage-I/O-parallelism effect is
deterministic in CI; data correctness is backed by the real in-memory
files underneath.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

from repro.core import (DeviceProfile, Foreactor, MemDevice, SimulatedDevice)

#: CI-friendly profile: same shape as REMOTE_PROFILE, smaller constants
BENCH_PROFILE = DeviceProfile(channels=16, base_latency=1.2e-3,
                              metadata_latency=1.0e-3, per_byte=1.0e-9,
                              crossing_cost=4e-6)

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def timeit(fn: Callable, n: int = 1, warmup: int = 0) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


def timeit_min(fn: Callable, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-N wall time: the min filters CI scheduler noise (2-vCPU
    containers), which a mean would fold into the measurement."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def make_files(inner: MemDevice, root: str, n: int, size: int) -> List[str]:
    rng = np.random.default_rng(0)
    paths = []
    for i in range(n):
        p = f"{root}/f{i:04d}"
        fd = inner.open(p, "w")
        inner.pwrite(fd, rng.bytes(size), 0)
        inner.close(fd)
        paths.append(p)
    return paths


def sim(inner: MemDevice, cache_bytes: int = 0,
        profile: DeviceProfile = BENCH_PROFILE) -> SimulatedDevice:
    return SimulatedDevice(inner, profile, cache_bytes=cache_bytes)


def fmt(rows: List[Row]) -> List[str]:
    return [f"{name},{us:.1f},{derived}" for name, us, derived in rows]


#: JSON result conventions: every benchmark that produces structured results
#: (not just CSV rows) writes them to ``benchmarks/results/<name>.json`` via
#: :func:`write_results` — a dict with a ``"benchmark"`` key naming the
#: section and whatever measurement payload the section defines.
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")


def write_results(name: str, payload: Dict[str, Any]) -> str:
    """Write a benchmark's structured results; returns the JSON path."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    out = {"benchmark": name}
    out.update(payload)
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def zipf_keys(n_keys: int, n_samples: int, theta: float, rng) -> np.ndarray:
    """Zipfian sampling over [0, n_keys) with skew theta (YCSB-style)."""
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    probs = 1.0 / ranks ** theta
    probs /= probs.sum()
    return rng.choice(n_keys, size=n_samples, p=probs)
