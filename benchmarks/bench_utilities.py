"""Paper Fig. 6: du (fstat loop) and cp (Link'ed read->write loop)."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import Foreactor, MemDevice, io
from repro.store import plugins
from repro.store.fileutils import cp_file, du_dir

from .common import BENCH_PROFILE, Row, make_files, sim, timeit


def bench_du(n_files: int = 100) -> List[Row]:
    """Fig. 6(a): completion time of du vs pre-issuing depth."""
    inner = MemDevice()
    make_files(inner, "/dir", n_files, 64)
    rows: List[Row] = []
    t_sync = None
    for depth, label in [(0, "sync"), (4, "depth4"), (16, "depth16")]:
        dev = sim(inner)
        fa = Foreactor(device=dev, backend="io_uring", depth=depth)
        plugins.register_all(fa)
        fn = fa.wrap("du", plugins.capture_du)(du_dir) if depth else du_dir
        t = timeit(lambda: fn(dev, "/dir"), n=3)
        if depth == 0:
            t_sync = t
        impr = f"improvement={100 * (1 - t / t_sync):.0f}%" if t_sync else ""
        rows.append((f"du_files{n_files}_{label}", t * 1e6, impr))
        fa.shutdown()
    return rows


def bench_cp(sizes=(256 * 1024, 1024 * 1024)) -> List[Row]:
    """Fig. 6(b): cp completion time, 128 KB copy buffers."""
    rows: List[Row] = []
    for size in sizes:
        inner = MemDevice()
        rng = np.random.default_rng(1)
        fd = inner.open("/src", "w")
        inner.pwrite(fd, rng.bytes(size), 0)
        inner.close(fd)
        dev = sim(inner)
        t_sync = timeit(lambda: cp_file(dev, "/src", "/dst_sync", 64 * 1024), n=2)
        fa = Foreactor(device=dev, backend="io_uring", depth=16)
        plugins.register_all(fa)
        cp = fa.wrap("cp", plugins.capture_cp)(cp_file)
        t_fa = timeit(lambda: cp(dev, "/src", "/dst_fa", 64 * 1024), n=2)
        # correctness: both copies identical to source
        f1 = inner.open("/dst_fa", "r")
        f2 = inner.open("/src", "r")
        assert inner.pread(f1, size, 0) == inner.pread(f2, size, 0)
        rows.append((f"cp_{size >> 10}KiB_sync", t_sync * 1e6, ""))
        rows.append((f"cp_{size >> 10}KiB_foreactor", t_fa * 1e6,
                     f"improvement={100 * (1 - t_fa / t_sync):.0f}%"))
        fa.shutdown()
    return rows


def run() -> List[Row]:
    return bench_du() + bench_cp()
