"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps through the full stack (foreactor data pipeline, jitted
train step, async checkpointing, restore-on-restart).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

This is the runnable ~100M config; it is CPU-heavy (~1-2 s/step).  For a
30-second sanity run use --tiny.
"""

import argparse

from repro.checkpoint import CheckpointManager
from repro.core import Foreactor, OSDevice
from repro.data import (DataConfig, ShardedTokenDataset, TokenBatchLoader,
                        write_synthetic_dataset)
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--workdir", default="/tmp/repro_100m")
args = ap.parse_args()

if args.tiny:
    cfg = ModelConfig(name="llama-tiny", vocab_size=2048, d_model=128,
                      n_layers=4, n_heads=8, n_kv_heads=2, d_ff=352,
                      param_dtype="float32", compute_dtype="float32",
                      loss_chunk=64, remat=False)
    seq, batch = 128, 8
else:
    # ~100M params: 12 x (d=768, ff=2048) + 32k vocab
    cfg = ModelConfig(name="llama-100m", vocab_size=32000, d_model=768,
                      n_layers=12, n_heads=12, n_kv_heads=4, d_ff=2048,
                      param_dtype="float32", compute_dtype="float32",
                      loss_chunk=128, remat=False)
    seq, batch = 256, 8

model = build_model(cfg)
device = OSDevice()
fa = Foreactor(device=device, backend="io_uring", depth=32)
dcfg = DataConfig(seq_len=seq, batch_size=batch, seed=0)
try:
    device.fstatat(f"{args.workdir}/data/shard_00000.rio")
except FileNotFoundError:
    write_synthetic_dataset(device, f"{args.workdir}/data", dcfg, 4, 128,
                            cfg.vocab_size)
ds = ShardedTokenDataset(device,
                         [f"{args.workdir}/data/shard_{i:05d}.rio" for i in range(4)])
loader = TokenBatchLoader(ds, dcfg, fa=fa)
ckpt = CheckpointManager(device, f"{args.workdir}/ckpt", fa=fa, num_shards=4)
opt = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
trainer = Trainer(model, opt, loader, ckpt, make_host_mesh(),
                  TrainerConfig(steps=args.steps, ckpt_every=50, log_every=10))
out = trainer.fit()
n_params = sum(int(x.size) for x in __import__("jax").tree.leaves(out["state"]["params"]))
print(f"params: {n_params/1e6:.1f}M  loss {out['losses'][0]:.3f} -> "
      f"{out['losses'][-1]:.3f} over {out['final_step']} steps")
loader.close()
fa.shutdown()
