"""Quickstart: explicit speculation on a serial stat loop in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

from repro.core import (DeviceProfile, Foreactor, GraphBuilder, MemDevice,
                        SimulatedDevice, Sys, io)

# 1. a slow device with 16-way internal parallelism + some files
inner = MemDevice()
for i in range(60):
    fd = inner.open(f"/photos/img{i:03d}", "w")
    inner.pwrite(fd, b"\xff" * (1000 + i), 0)
    inner.close(fd)
dev = SimulatedDevice(inner, DeviceProfile(channels=16, metadata_latency=2e-3))


# 2. the application function — ordinary serial code
def total_size(paths):
    return sum(io.fstatat(dev, p).st_size for p in paths)


# 3. its foreaction graph (paper Fig. 4a): a loop of independent fstats
def build_graph():
    b = GraphBuilder("stat_loop")
    b.AddSyscallNode("fstat", Sys.FSTATAT,
                     lambda ctx, ep: ((ctx["paths"][ep[0]],), False)
                     if ep[0] < len(ctx["paths"]) else None)
    b.AddBranchingNode("more", lambda ctx, ep: 0 if ep[0] + 1 < len(ctx["paths"]) else 1)
    b.SyscallSetNext("fstat", "more")
    b.BranchAppendChild("more", "fstat", loopback=True)
    b.BranchAppendChild("more", None)
    return b.Build()


paths = [f"/photos/img{i:03d}" for i in range(60)]
fa = Foreactor(device=dev, backend="io_uring", depth=16)
fa.register("stat_loop", build_graph)
speculated = fa.wrap("stat_loop", lambda paths: {"paths": paths})(total_size)

t0 = time.perf_counter(); serial = total_size(paths); t_serial = time.perf_counter() - t0
t0 = time.perf_counter(); fast = speculated(paths); t_fast = time.perf_counter() - t0
assert serial == fast
print(f"serial:     {t_serial*1e3:6.1f} ms")
print(f"speculated: {t_fast*1e3:6.1f} ms   ({t_serial/t_fast:.1f}x, identical result)")
print(f"engine: {fa.total_stats.pre_issued} pre-issued, "
      f"{fa.total_stats.served_async} served async")
fa.shutdown()
