"""Serve batched Get requests from an LSM record store with explicit
speculation (the paper's LevelDB case as a feature-store server).

    PYTHONPATH=src python examples/lsm_serving.py
"""

import time

import numpy as np

from repro.core import DeviceProfile, Foreactor, MemDevice, SimulatedDevice
from repro.store import plugins
from repro.store.lsm import LSMTree

# build a store with overlapping L0 tables (long Get chains)
rng = np.random.default_rng(0)
inner = MemDevice()
store = LSMTree(inner, "/features", memtable_limit_bytes=1 << 15,
                l0_limit=10**6, fsync_writes=False)
ref = {}
for k in rng.permutation(3000):
    v = rng.bytes(128)
    store.put(int(k), v)
    ref[int(k)] = v
store.flush()
print(f"store: {store.table_count()} tables, "
      f"levels {[len(l) for l in store.levels]}")

dev = SimulatedDevice(inner, DeviceProfile(channels=16, base_latency=1e-3),
                      cache_bytes=1 << 18)
server = LSMTree.open_existing(dev, "/features")
fa = Foreactor(device=dev, backend="io_uring", depth=16)
plugins.register_all(fa)
get = fa.wrap("lsm_get", plugins.capture_lsm_get)(lambda s, k: s.get(k))

requests = [int(k) for k in rng.choice(3000, 100)]
t0 = time.perf_counter()
for k in requests:
    assert server.get(k) == ref[k]
t_serial = time.perf_counter() - t0
t0 = time.perf_counter()
for k in requests:
    assert get(server, k) == ref[k]
t_spec = time.perf_counter() - t0
print(f"100 Gets serial:    {t_serial*1e3:6.0f} ms ({100/t_serial:.0f} req/s)")
print(f"100 Gets speculated:{t_spec*1e3:6.0f} ms ({100/t_spec:.0f} req/s)  "
      f"-> {t_serial/t_spec:.2f}x")
fa.shutdown()
