"""Disaster-recovery drill: checkpoint a model, corrupt the primary copy,
replicate to a second tier with Link'ed read->write chains, restore.

    PYTHONPATH=src python examples/checkpoint_dr.py
"""

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import DeviceProfile, Foreactor, MemDevice, SimulatedDevice

inner = MemDevice()
dev = SimulatedDevice(inner, DeviceProfile(channels=16, base_latency=5e-4))
fa = Foreactor(device=dev, backend="io_uring", depth=32)

primary = CheckpointManager(dev, "/primary", fa=fa, num_shards=8,
                            chunk_bytes=1 << 16)
replica = CheckpointManager(dev, "/replica", fa=fa, num_shards=8,
                            chunk_bytes=1 << 16)

state = {"w": np.random.default_rng(0).normal(size=(512, 512)).astype(np.float32),
         "step": np.asarray(123, np.int32)}
primary.save(123, state, extra={"note": "nightly"})
primary.replicate(123, replica)
print("saved + replicated step 123")

# corrupt the primary
fd = inner.open("/primary/step_0000000123/shard_0000.bin", "w")
inner.pwrite(fd, b"bitrot", 0)
inner.close(fd)
assert primary.restore_latest(like=state) is None  # primary unusable
out = replica.restore_latest(like=state)
assert out is not None and out[0] == 123
np.testing.assert_array_equal(out[1]["w"], state["w"])
print("primary corrupted -> replica restore OK (crc-verified)")
fa.shutdown()
