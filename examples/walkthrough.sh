#!/usr/bin/env bash
# The README quickstart, executable: train with write-behind checkpointing,
# die mid-run, restore from the emergency checkpoint, verify the state.
# CI runs this script (.github/workflows/ci.yml, docs job) so the
# walkthrough in README.md cannot rot.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

WORK="${WORK:-$(mktemp -d)}"
echo "== walkthrough: working under $WORK"

TRAIN="python -m repro.launch.train --arch tinyllama-1.1b --smoke
       --steps 8 --batch 2 --seq 32 --ckpt-every 4
       --shards 2 --records-per-shard 32
       --data $WORK/data --ckpt $WORK/ckpt"

echo "== 1. train with write-behind checkpointing, kill at step 6"
if $TRAIN --kill-at 6; then
    echo "expected the simulated node failure to abort the run" >&2
    exit 1
fi
echo "   (died as intended; an emergency checkpoint was written)"

echo "== 2. rerun the same command: restores and finishes"
$TRAIN | tee "$WORK/resume.log"
grep -q "restored step" "$WORK/resume.log"
grep -q "done: step 8" "$WORK/resume.log"

echo "== 3. verify the committed checkpoint restores cleanly"
python - "$WORK/ckpt" <<'EOF'
import sys
from repro.core import OSDevice
from repro.checkpoint import CheckpointManager

mgr = CheckpointManager(OSDevice(), sys.argv[1], num_shards=4)
steps = mgr.committed_steps()
assert steps, "no committed checkpoints found"
out = mgr.restore_latest()
assert out is not None, "latest checkpoint failed validation"
step, tree, extra = out
assert step == max(steps) and int(extra["step"]) >= 8, (step, extra)
print(f"   restored step {step} OK: {len(tree)} leaves, extra={extra}")
mgr.fa.shutdown()
EOF

echo "== 4. lifecycle: retention + delta checkpoints survive a mid-run kill"
# Same shape, now with a retention policy (keep the newest 2 retention
# units) and alternating full/delta saves; the run dies mid-chain, the
# rerun restores through the delta chain, and every save GCs superseded
# checkpoints via the speculated tombstone/unlink graph.
LC="python -m repro.launch.train --arch tinyllama-1.1b --smoke
    --steps 11 --batch 2 --seq 32 --ckpt-every 2
    --shards 2 --records-per-shard 32
    --keep-last 2 --delta-every 1
    --data $WORK/data --ckpt $WORK/ckpt-lc"
if $LC --kill-at 9; then
    echo "expected the simulated node failure to abort the run" >&2
    exit 1
fi
$LC | tee "$WORK/resume-lc.log"
grep -q "restored step" "$WORK/resume-lc.log"
grep -q "done: step 11" "$WORK/resume-lc.log"

python - "$WORK/ckpt-lc" <<'EOF'
import sys
from repro.core import OSDevice
from repro.checkpoint import CheckpointManager

mgr = CheckpointManager(OSDevice(), sys.argv[1], num_shards=4)
steps = mgr.committed_steps()
assert steps and max(steps) == 11, steps
assert 2 not in steps and len(steps) <= 4, f"retention did not collect: {steps}"
kinds = {s: mgr.read_manifest(s)["kind"] for s in steps}
assert "delta" in kinds.values(), kinds
for s, k in kinds.items():
    if k == "delta":
        assert mgr.read_manifest(s)["base"] in steps, (s, steps)
out = mgr.restore_latest()
assert out is not None, "latest checkpoint failed validation"
step, tree, extra = out
print(f"   lifecycle OK: kept {steps} ({sorted(kinds.values())}), restored step {step}")
mgr.fa.shutdown()
EOF

echo "== walkthrough OK"
