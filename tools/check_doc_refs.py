#!/usr/bin/env python3
"""Fail if a doc references a repository path that no longer exists.

Scans markdown files for path-like references (``src/...``, ``tests/...``,
``benchmarks/...``, ``docs/...``, ``examples/...``) and dotted module names
(``repro.core.engine``), and checks each against the working tree. Keeps
docs/ARCHITECTURE.md honest as modules move (run by the CI docs job).

Usage: python tools/check_doc_refs.py docs/ARCHITECTURE.md README.md ...
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PATH_RE = re.compile(
    r"\b(?:src|tests|benchmarks|docs|examples|tools)/[\w./-]+\.(?:py|md|json|yml)\b"
)
MODULE_RE = re.compile(r"\brepro(?:\.\w+)+\b")

#: paths docs may legitimately reference before they exist at check time
GENERATED = {"benchmarks/results/sharding.json"}


def module_exists(dotted: str) -> bool:
    parts = dotted.split(".")
    # Trailing CapitalCase components are class/constant attributes
    # (repro.core.device.ShardedDevice); strip those. A lowercase tail is a
    # module name and must resolve — otherwise a deleted module would pass as
    # long as its parent package survives.
    while len(parts) > 1 and not parts[-1][:1].islower():
        parts = parts[:-1]
    base = os.path.join(REPO, "src", *parts)
    return os.path.isfile(base + ".py") or os.path.isdir(base)


def check(path: str) -> list:
    with open(path) as f:
        text = f.read()
    missing = []
    for ref in sorted(set(PATH_RE.findall(text))):
        if ref in GENERATED:
            continue
        if not os.path.exists(os.path.join(REPO, ref)):
            missing.append(ref)
    for ref in sorted(set(MODULE_RE.findall(text))):
        if not module_exists(ref):
            missing.append(ref)
    return missing


def main(argv) -> int:
    files = argv or ["docs/ARCHITECTURE.md"]
    bad = 0
    for f in files:
        missing = check(os.path.join(REPO, f))
        for ref in missing:
            print(f"{f}: dangling reference: {ref}")
        bad += len(missing)
    if bad:
        print(f"{bad} dangling reference(s)")
        return 1
    print(f"ok: {len(files)} file(s), no dangling references")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
