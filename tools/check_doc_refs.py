#!/usr/bin/env python3
"""Fail if a doc references a repository path that no longer exists, or
embeds a ``dot`` graph that no buildable graph renders.

Two checks per markdown file:

1. **Path/module references** — path-like references (``src/...``,
   ``tests/...``, ...) must exist in the working tree, and dotted
   references (``repro.core.engine``, ``repro.core.engine.SpecSession``,
   ``repro.store.staging.StagingTxn.finalize``) must resolve against the
   *importable* tree: the longest filesystem prefix is imported and the
   remaining components are walked with ``getattr`` — a renamed class or
   deleted method dangles even though its module file survives.
2. **Fenced ``dot`` blocks** — every ```` ```dot ```` block must parse
   against the ``to_dot()`` line grammar *and* byte-for-byte match the
   ``to_dot()`` output of a buildable graph (the hand-written plugin
   graphs, the reusable patterns, or the mined reference graphs from
   ``repro.store.plugins.mine_reference_graphs``).  Docs cannot drift from
   the graphs the code actually builds.

Usage: python tools/check_doc_refs.py docs/ARCHITECTURE.md README.md ...
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PATH_RE = re.compile(
    r"\b(?:src|tests|benchmarks|docs|examples|tools)/[\w./-]+\.(?:py|md|json|yml)\b"
)
MODULE_RE = re.compile(r"\brepro(?:\.\w+)+\b")
DOT_BLOCK_RE = re.compile(r"^```dot\n(.*?)^```", re.MULTILINE | re.DOTALL)

#: the exact line shapes ForeactionGraph.to_dot() can emit
DOT_LINE_RES = [
    re.compile(r'^digraph "[^"]+" \{$'),
    re.compile(r"^  rankdir=LR;$"),
    re.compile(r"^  [SE] \[shape=(?:double)?circle\];$"),
    re.compile(r'^  "[^"]+" \[shape=(?:box, label="[^"]*"|diamond)\];$'),
    re.compile(r'^  (?:S|"[^"]+") -> (?:E|"[^"]+")'
               r'(?: \[(?:style=dashed)?(?:, )?(?:label="loop \d+")?\])?;$'),
    re.compile(r"^\}$"),
]

#: paths docs may legitimately reference before they exist at check time
GENERATED = {"benchmarks/results/sharding.json",
             "benchmarks/results/adaptive.json",
             "benchmarks/results/serve.json",
             "benchmarks/results/write.json"}


def _buildable_dots() -> dict:
    """to_dot() renderings of every graph the repo can build, keyed by a
    human-readable origin (for error messages)."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.patterns import PATTERNS
    from repro.store import plugins

    dots = {}
    for name, builder in (
        ("plugins.build_du_graph", plugins.build_du_graph),
        ("plugins.build_cp_graph", plugins.build_cp_graph),
        ("plugins.build_bptree_scan_graph", plugins.build_bptree_scan_graph),
        ("plugins.build_bptree_load_graph", plugins.build_bptree_load_graph),
        ("plugins.build_lsm_get_graph", plugins.build_lsm_get_graph),
    ):
        dots[name] = builder().to_dot()
    for name, builder in PATTERNS.items():
        dots[f"patterns.{name}"] = builder().to_dot()
    for name, mined in plugins.mine_reference_graphs().items():
        dots[f"mined.{name}"] = mined.graph.to_dot()
    return dots


def check_dot_blocks(path: str, get_dots) -> list:
    """Problems with the fenced dot blocks of one markdown file.
    ``get_dots`` is called lazily on the first block found, so files
    without dot blocks never pay the graph-building (or numpy) cost."""
    with open(path) as f:
        text = f.read()
    problems = []
    dots = None
    for i, m in enumerate(DOT_BLOCK_RE.finditer(text)):
        if dots is None:
            dots = get_dots()
        block = m.group(1).rstrip("\n")
        label = f"dot block #{i + 1}"
        for line in block.split("\n"):
            if not any(r.match(line) for r in DOT_LINE_RES):
                problems.append(f"{label}: unparseable line: {line!r}")
        if block not in dots.values():
            problems.append(
                f"{label}: matches no buildable graph's to_dot() "
                f"(known: {', '.join(sorted(dots))})"
            )
    return problems


def _fs_exists(parts) -> bool:
    base = os.path.join(REPO, "src", *parts)
    return os.path.isfile(base + ".py") or os.path.isdir(base)


def module_exists(dotted: str) -> bool:
    """Resolve ``repro[.module]*[.Symbol[.attr]*]`` against the importable
    tree: find the longest prefix that is a module/package on disk, import
    it, then getattr-walk the remainder.  ``repro.core.engine.SpecSession``
    dangles if the class is renamed; ``repro.core.api.io.pwrite`` dangles if
    the method is dropped — not just when whole files disappear."""
    parts = dotted.split(".")
    k = len(parts)
    while k > 1 and not _fs_exists(parts[:k]):
        k -= 1
    if not _fs_exists(parts[:k]):
        return False
    if k == len(parts):
        return True
    import importlib

    src = os.path.join(REPO, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    try:
        obj = importlib.import_module(".".join(parts[:k]))
    except Exception as e:  # import failure = the reference cannot resolve
        print(f"  (import {'.'.join(parts[:k])} failed: {e!r})")
        return False
    for attr in parts[k:]:
        if not hasattr(obj, attr):
            return False
        obj = getattr(obj, attr)
    return True


def check(path: str) -> list:
    with open(path) as f:
        text = f.read()
    missing = []
    for ref in sorted(set(PATH_RE.findall(text))):
        if ref in GENERATED:
            continue
        if not os.path.exists(os.path.join(REPO, ref)):
            missing.append(ref)
    for ref in sorted(set(MODULE_RE.findall(text))):
        if not module_exists(ref):
            missing.append(ref)
    return missing


def main(argv) -> int:
    files = argv or ["docs/ARCHITECTURE.md"]
    cache: dict = {}

    def get_dots() -> dict:
        if not cache:
            cache.update(_buildable_dots())
        return cache

    bad = 0
    for f in files:
        full = os.path.join(REPO, f)
        missing = check(full)
        for ref in missing:
            print(f"{f}: dangling reference: {ref}")
        problems = check_dot_blocks(full, get_dots)
        for p in problems:
            print(f"{f}: {p}")
        bad += len(missing) + len(problems)
    if bad:
        print(f"{bad} problem(s)")
        return 1
    print(f"ok: {len(files)} file(s), no dangling references, "
          f"all dot blocks match buildable graphs")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
