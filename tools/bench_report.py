#!/usr/bin/env python3
"""Render docs/BENCHMARKS.md from benchmarks/results/*.json.

The performance-trajectory doc is *generated*, never hand-copied: every
table is a deterministic function of the committed result files, so the doc
cannot drift from the numbers.  Regenerate after re-running a benchmark::

    python tools/bench_report.py            # rewrite docs/BENCHMARKS.md
    python tools/bench_report.py --check    # CI: fail if the doc is stale

Each known result file (sharding, adaptive, serve, write) has a renderer;
unknown result files are listed so they are never silently dropped.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "benchmarks", "results")
DOC = os.path.join(REPO, "docs", "BENCHMARKS.md")

HEADER = """\
# Benchmark trajectory

Performance results across this repository's PR sequence, rendered from the
committed result files in `benchmarks/results/` by `tools/bench_report.py`
(CI runs `tools/bench_report.py --check`, so this document cannot drift from
the numbers).  Every benchmark runs on `SimulatedDevice` (deterministic
Fig.-1 cost model) inside a CI container; see each `benchmarks/bench_*.py`
section header for the workload details and the exact device profile.

Regenerate with:

```sh
PYTHONPATH=src python -m benchmarks.bench_<name>   # refresh one result file
python tools/bench_report.py                       # re-render this document
```
"""


def _load(name: str) -> Optional[Dict]:
    path = os.path.join(RESULTS, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _table(headers: List[str], rows: List[List[str]]) -> List[str]:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    out += ["| " + " | ".join(r) + " |" for r in rows]
    return out


def _ms(seconds: float) -> str:
    return f"{seconds * 1e3:.1f}"


def render_sharding(d: Dict) -> List[str]:
    out = ["## Multi-device sharding (`benchmarks/bench_sharding.py`)", ""]
    out.append("Aggregate bandwidth (MB/s) vs device count: one queue pair "
               "per sub-device (`multi_queue`) against one global queue pair "
               "(`io_uring`) and the serial baseline (`sync`).")
    for section in ("restore", "pipeline"):
        sec = d[section]
        counts = [str(n) for n in sec["config"]["device_counts"]]
        rows = []
        for backend in ("sync", "io_uring", "multi_queue"):
            rows.append([f"`{backend}`"] +
                        [f"{sec[backend][n]['bandwidth_mb_s']:.2f}"
                         for n in counts])
        out += ["", f"### {section}", ""]
        out += _table(["backend \\ devices"] + counts, rows)
        out += ["",
                f"Multi-queue speedup at 4 devices: "
                f"**{sec['speedup_multi_queue_4dev']:.2f}x** over 1 device."]
    return out


def render_adaptive(d: Dict) -> List[str]:
    out = ["## Adaptive speculation depth (`benchmarks/bench_adaptive.py`)",
           "",
           "Wall seconds per workload: fixed depths vs the "
           "`DepthController`; adaptive must match the best fixed depth "
           "without knowing it in advance."]
    depths = [str(x) for x in d["config"]["fixed_depths"]]
    rows = []
    for wl in ("stat_batch", "scan_deep", "search_early_exit"):
        s = d["summary"][wl]
        rows.append([f"`{wl}`"] +
                    [_ms(d[wl][x]["seconds"]) for x in depths] +
                    [_ms(d[wl]["adaptive"]["seconds"]),
                     str(s["best_fixed_depth"]),
                     f"{s['worst_vs_adaptive']:.1f}x"])
    out += [""]
    out += _table(["workload \\ depth (ms)"] + depths +
                  ["adaptive", "best fixed", "vs worst"], rows)
    return out


def render_serve(d: Dict) -> List[str]:
    s = d["summary"]
    out = ["## Multi-tenant serving (`benchmarks/bench_serve.py`)", "",
           "Closed-loop clients on one shared backend (`shared=True`, slot "
           "scheduler) vs per-thread isolated queue pairs vs sync."]
    counts = sorted(d["sweep"], key=int)
    rows = []
    for mode in ("sync", "isolated", "shared"):
        row = [f"`{mode}`"]
        for n in counts:
            cell = d["sweep"][n][mode]
            p99 = cell["classes"]["high"]["p99_ms"]
            row.append(f"{cell['throughput_ops']:.0f} ops/s, "
                       f"p99 {p99:.1f} ms")
        rows.append(row)
    out += [""]
    out += _table(["mode \\ clients"] + counts, rows)
    out += ["",
            f"At {s['clients']} clients: shared p99 is "
            f"**{s['shared_p99_speedup']:.2f}x** better than sync, "
            f"throughput within "
            f"{(1 - s['shared_tput_vs_isolated']) * 100:.0f}% of isolated; "
            f"high-priority p99 moves "
            f"{s['high_pri_p99_delta'] * 100:+.0f}% under low-priority "
            f"restore load."]
    return out


def render_write(d: Dict) -> List[str]:
    save = d["save"]
    out = ["## Write-path speculation (`benchmarks/bench_write.py`)", "",
           "Undoable writes (staging extents + undo log + publish "
           "barriers) let the engine pre-issue the whole checkpoint-save "
           "chain; `serial` is the pre-staging write path (sync backend)."]
    counts = [str(n) for n in save["config"]["shard_counts"]]
    rows = []
    for mode in save["config"]["modes"]:
        rows.append([f"`{mode}`"] +
                    [_ms(save[mode][n]["seconds"]) for n in counts])
    out += ["", "### Checkpoint save (ms per save)", ""]
    out += _table(["mode \\ shards"] + counts, rows)
    out += ["",
            f"Best speculated save at 4 shards: "
            f"**{save['speedup_4shards']:.2f}x** faster than the serial "
            f"write path (acceptance gate: >= 1.5x)."]
    rs = d["record_shard"]
    out += ["", "### Record-shard write (`write_shard`)", ""]
    out += _table(["path", "seconds", "MB/s"], [
        ["serial append loop", f"{rs['serial']['seconds']:.3f}",
         f"{rs['serial']['mb_per_s']:.1f}"],
        ["`write_file` graph", f"{rs['spec']['seconds']:.3f}",
         f"{rs['spec']['mb_per_s']:.1f}"],
    ])
    out += ["", f"Speedup: **{rs['speedup']:.2f}x**."]
    wb = d["write_behind"]
    out += ["", "### Write-behind checkpointing", ""]
    out += _table(["mode", "wall (s)", "train-thread stall (s)"], [
        ["serial saves", f"{wb['serial']['wall_seconds']:.2f}",
         f"{wb['serial']['stall_seconds']:.2f}"],
        ["write-behind", f"{wb['write_behind']['wall_seconds']:.2f}",
         f"{wb['write_behind']['stall_seconds']:.2f}"],
    ])
    out += ["",
            f"Overlapping the speculated save graph with step compute cuts "
            f"the training-thread stall to "
            f"{wb['stall_ratio'] * 100:.0f}% of the serial path's."]
    delta = d.get("delta")
    if delta is not None:
        out += ["", "### Delta checkpoints (bytes written vs full save)", ""]
        rows = []
        for frac in delta["config"]["churns"]:
            cell = delta[f"churn_{frac:g}"]
            rows.append([f"{frac * 100:g}%",
                         str(cell["changed_extents_per_save"]),
                         f"{cell['full_bytes'] / 1e6:.2f}",
                         f"{cell['mean_delta_bytes'] / 1e6:.2f}",
                         f"**{cell['bytes_ratio']:.3f}x**"])
        out += _table(["extent churn", "changed extents/save", "full (MB)",
                       "delta (MB)", "bytes ratio"], rows)
        out += ["",
                f"`save(..., delta=True)` writes only the extents whose "
                f"CRCs changed against the newest committed chain "
                f"({delta['config']['num_extents']} extents of "
                f"{delta['config']['chunk_bytes'] // 1024} KiB; chain depth "
                f"{delta['config']['chain_len']}); restore overlays base + "
                f"deltas back to a byte-identical tree.  Acceptance gate: "
                f"<= 0.2x at 10% churn — measured "
                f"**{delta['churn_0.1']['bytes_ratio']:.3f}x**."]
    return out


def render_overhead(d: Dict) -> List[str]:
    out = ["## Engine overhead (`benchmarks/bench_overhead.py`)", "",
           "Fig. 10's framework-overhead lines, isolated: the peek "
           "algorithm's pure interpretation cost (sync backend, no workers, "
           "no simulated latency) for the compiled-plan interpreter vs the "
           "committed pre-refactor object walker "
           f"({d['config']['baseline_commit']}), and result delivery with "
           "the registered buffer pool on vs off.  CI's perf-smoke job "
           "re-measures in dry-run mode and gates on these numbers."]
    p = d["peek"]
    rows = [
        ["`lsm_get` (us/Get)",
         f"{p['baseline']['lsm_get_us_per_get']:.1f}",
         f"{p['plan']['lsm_get_us_per_get']:.1f}",
         f"**{p['speedup_lsm_get_per_get']:.2f}x**"],
        ["`weak_chain` (us/intercept)",
         f"{p['baseline']['weak_chain_us_per_intercept']:.1f}",
         f"{p['plan']['weak_chain_us_per_intercept']:.1f}",
         f"{p['speedup_weak_chain']:.2f}x"],
        ["`extent_loop` (us/intercept)",
         f"{p['baseline']['extent_loop_us_per_intercept']:.1f}",
         f"{p['plan']['extent_loop_us_per_intercept']:.1f}",
         f"{p['speedup_extent_loop']:.2f}x"],
    ]
    out += ["", "### Peek algorithm (Algorithm 1 interpretation cost)", ""]
    out += _table(["workload", "object walker", "plan interpreter",
                   "speedup"], rows)
    out += ["",
            f"Acceptance gate: >= 2x per speculated Get — measured "
            f"**{p['speedup_lsm_get_per_get']:.2f}x**."]
    rc = d["result_copy"]
    out += ["", "### Result delivery (registered buffer pool)", ""]
    out += _table(["path", "us/op"], [
        ["allocate-per-request (pool off)",
         f"{rc['pool_off']['us_per_op']:.1f}"],
        [f"registered buffers (pool on, hit rate "
         f"{rc['pool_on']['hit_rate'] * 100:.0f}%)",
         f"{rc['pool_on']['us_per_op']:.1f}"],
    ])
    out += ["",
            f"{rc['config']['n']} preads of "
            f"{rc['config']['size_bytes'] // 1024} KiB submitted as one "
            f"batch: leasing is **{rc['speedup']:.2f}x** faster end to end "
            "(one copy into recycled memory + one bounded materialize "
            "memcpy, instead of two allocations per request; wasted "
            "speculative reads allocate nothing at all)."]
    comp = d.get("completion")
    if comp is not None:
        out += ["", "### Completion primitive (pooled stripes vs "
                "per-request Event)", ""]
        out += _table(
            ["path", "per-request Event (us)", "pooled stripes (us)",
             "speedup"],
            [["claim + finish + harvest",
              f"{comp['baseline']['lifecycle_us_per_req']:.2f}",
              f"{comp['pooled']['lifecycle_us_per_req']:.2f}",
              f"**{comp['speedup_lifecycle']:.2f}x**"],
             ["cancel + poll (wasted speculation)",
              f"{comp['baseline']['cancel_us_per_req']:.2f}",
              f"{comp['pooled']['cancel_us_per_req']:.2f}",
              f"{comp['speedup_cancel']:.2f}x"]])
        out += ["",
                "Every `IORequest` used to allocate its own "
                "`threading.Event` plus a claim lock; completion now rides "
                "a fixed stripe table (`repro.core.completion`, the CQ "
                "analogue), so the per-record constant stops scaling the "
                "10k-session open-loop runs."]
    return out


def render_openloop(d: Dict) -> List[str]:
    s = d["summary"]
    cfg = d["config"]
    out = ["## Open-loop serving to saturation "
           "(`benchmarks/bench_openloop.py`)", "",
           "Fixed-rate Poisson arrivals (one fresh tenant session each, "
           f"{cfg['rate_per_session']}/s per session over "
           f"{cfg['duration_s']}s windows) against the serving substrate, "
           "regardless of whether the server keeps up; latency is "
           "virtual-time from the *scheduled* arrival (wrk2-style, no "
           "coordinated omission).  `shared` = one queue pair + slot "
           "scheduler; `sync` = no speculation."]
    cells = {m: {c["sessions"]: c for c in d["sweep"][m]} for m in d["sweep"]}
    sessions = [c["sessions"] for c in d["sweep"]["shared"]]
    rows = []
    for n in sessions:
        sy, sh = cells["sync"][n], cells["shared"][n]
        rows.append([
            str(n), f"{sh['offered_rate']:.0f}",
            f"{sy['achieved_rate']:.0f}", f"{sy['p99_ms']:.1f}",
            f"{sh['achieved_rate']:.0f}", f"{sh['p99_ms']:.1f}",
            str(max(sy["max_inflight_sessions"],
                    sh["max_inflight_sessions"]))])
    out += [""]
    out += _table(["sessions", "offered (1/s)", "sync achieved",
                   "sync p99 (ms)", "shared achieved", "shared p99 (ms)",
                   "peak in-flight"], rows)
    out += ["",
            f"{s['total_sessions']} sessions total across the sweep, "
            f"peaking at **{s['max_inflight_sessions']} concurrent "
            f"in-flight sessions**.  The shared mode stays sustained "
            f"through {s['knee_sessions']['shared']} sessions "
            f"({s['knee_offered_rate']:.0f}/s offered) — at that knee its "
            f"p99 is **{s['shared_p99_speedup_at_knee']:.2f}x** better "
            f"than sync serving the identical arrival trace "
            f"({s['shared_p99_at_knee_ms']:.1f} ms vs "
            f"{s['sync_p99_at_knee_ms']:.1f} ms).  Past the knee both "
            "modes collapse into queueing delay — which is the point of "
            "an open loop: the backlog lands in the tail instead of "
            "silently throttling the load generator."]
    return out


def render_multiget(d: Dict) -> List[str]:
    s = d["summary"]
    cfg = d["config"]
    out = ["## Batched multiget (`benchmarks/bench_multiget.py`)", "",
           "`LSMTree.multi_get` fans a whole batch of point lookups into "
           "one generated `lsm_multiget` plan via the futures API "
           "(`io.pread_async`): every key's candidate chain is flattened "
           "round-robin into a single pread loop and harvested at one "
           "barrier with per-key early exit.  The baseline is N sequential "
           "*speculated* `lsm_get` activations on the same io_uring queue "
           f"pair ({cfg['l0_tables']}-table candidate chains, "
           f"{cfg['n_keys']} keys)."]
    rows = [[str(c["batch"]), f"{c['sequential_ms']:.2f}",
             f"{c['multiget_ms']:.2f}", f"{c['speedup']:.2f}x"]
            for c in d["sweep"]]
    out += [""]
    out += _table(["batch", "sequential gets (ms)", "multiget (ms)",
                   "speedup"], rows)
    out += ["",
            f"At batch 16 the single scatter-gather plan is "
            f"**{s['speedup_at_16']:.2f}x** faster than 16 back-to-back "
            f"speculated gets (acceptance gate: >= 2x, enforced by the CI "
            f"multiget-smoke job); the gap is pure cross-key parallelism — "
            f"one session's submission batching and channel occupancy "
            f"instead of one blocking demand round per key."]
    return out


def render_remine(d: Dict) -> List[str]:
    s = d["summary"]
    cfg = d["config"]
    out = ["## Online re-mining after drift (`benchmarks/bench_remine.py`)",
           "",
           "A hot-table prefix scan whose mined graph bakes the table fd "
           "and offsets in as constants; `lsm.compact(0)` mid-serve closes "
           "those fds and moves the layout, so every pre-issue goes stale. "
           "With a `ReMiner` attached (sample every "
           f"{cfg['sample_every']}th activation, re-mine cadence "
           f"{cfg['remine_every']} traces), sampled post-compaction traces "
           "shadow-validate a candidate and hot-swap it in.  Benefit = "
           "`served_async / intercepted` over speculating sessions; every "
           "response stays byte-identical to the sync oracle across the "
           "swap boundary."]
    rows = []
    for p in d["phases"]:
        rows.append([f"`{p['phase']}`", str(p["ops"]),
                     f"{p['benefit']:.3f}", f"{p['ms_per_op']:.2f}",
                     str(p["stale_harvests"]), str(p["wasted"])])
    rows.append(["reference (fresh mine)",
                 str(cfg["phase_ops"]["recovered"]),
                 f"{s['benefit_reference']:.3f}", "—", "—", "—"])
    out += [""]
    out += _table(["phase", "ops", "benefit", "ms/op", "stale harvests",
                   "wasted"], rows)
    out += ["",
            f"Compaction drops the benefit from "
            f"{s['benefit_fresh']:.3f} to {s['benefit_stale']:.3f}; after "
            f"{d['remine']['swaps']} validated swaps "
            f"({d['remine']['rollbacks']} rollbacks) the re-mined graph "
            f"recovers **{s['recovery_ratio'] * 100:.0f}%** of a graph "
            f"freshly mined on the post-compaction layout (acceptance "
            f"gate: >= {80}%, enforced by the CI remine-smoke job)."]
    return out


def render_bandwidth(d: Dict) -> List[str]:
    out = ["## Raw-device bandwidth (`benchmarks/bench_bandwidth.py`)", "",
           "Direct-I/O lanes + extent coalescing "
           "(docs/ARCHITECTURE.md, \"Direct I/O & extent coalescing\"): "
           "aligned-buffer leases back O_DIRECT-style reads and the "
           "dispatch path fuses statically-adjacent same-fd preads into "
           "MB-scale super-reads.  Bandwidth (MB/s) vs shard count, with "
           "the fraction of the devices' raw streaming ceiling in "
           "parentheses."]
    modes = ("buffered", "buffered_coalesced", "direct", "direct_coalesced")
    for section in ("restore", "pipeline"):
        sec = d[section]
        counts = [str(n) for n in sec["config"]["shard_counts"]]
        rows = []
        for mode in modes:
            rows.append([f"`{mode}`"] +
                        [f"{sec[mode][n]['bandwidth_mb_s']:.1f} "
                         f"({sec[mode][n]['raw_fraction'] * 100:.0f}%)"
                         for n in counts])
        out += ["", f"### {section}", ""]
        out += _table(["mode \\ shards"] + counts, rows)
    rs = d["restore"]
    out += ["",
            f"Coalesced+direct restore scales "
            f"**{rs['scaling_4shards_direct_coalesced']:.2f}x** from 1 to "
            f"4 shards (acceptance gate: >= 2.5x, enforced by the CI "
            f"bandwidth-smoke job); coalescing alone is worth "
            f"{rs['coalesce_speedup_direct_coalesced_1sh']:.2f}x on a "
            f"single shard.  The sequential-order pipeline peaks at "
            f"**{d['pipeline']['best_mb_s_direct_coalesced']:.1f} MB/s** "
            f"with coalescing on (gate: >= 5x the committed sharding.json "
            f"io_uring pipeline baseline)."]
    return out


RENDERERS = [
    ("sharding", render_sharding),
    ("bandwidth", render_bandwidth),
    ("adaptive", render_adaptive),
    ("serve", render_serve),
    ("openloop", render_openloop),
    ("multiget", render_multiget),
    ("remine", render_remine),
    ("write", render_write),
    ("overhead", render_overhead),
]


def generate() -> str:
    parts = [HEADER]
    known = {name for name, _ in RENDERERS}
    for name, renderer in RENDERERS:
        d = _load(name)
        if d is None:
            parts.append(f"## {name}\n\n*(no committed results — run "
                         f"`python -m benchmarks.bench_{name}`)*")
            continue
        parts.append("\n".join(renderer(d)))
    extras = sorted(
        f[:-5] for f in os.listdir(RESULTS)
        if f.endswith(".json") and f[:-5] not in known
    ) if os.path.isdir(RESULTS) else []
    if extras:
        parts.append("## Other result files\n\n" +
                     "\n".join(f"* `benchmarks/results/{e}.json` (no "
                               f"renderer yet)" for e in extras))
    return "\n\n".join(parts) + "\n"


def main(argv: List[str]) -> int:
    text = generate()
    if "--check" in argv:
        if not os.path.exists(DOC):
            print(f"{DOC}: missing — run python tools/bench_report.py")
            return 1
        with open(DOC) as f:
            on_disk = f.read()
        if on_disk != text:
            print("docs/BENCHMARKS.md is stale: regenerate with "
                  "`python tools/bench_report.py`")
            return 1
        print("ok: docs/BENCHMARKS.md matches benchmarks/results/*.json")
        return 0
    with open(DOC, "w") as f:
        f.write(text)
    print(f"wrote {DOC}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
